//! Native bit-packed XNOR BNN inference engine.
//!
//! The in-pixel first layer emits *binary* activations, so the classifier
//! head can use the standard XNOR-Net trick: encode ±1 values as single
//! bits packed into `u64` lanes and evaluate each binary dot product as
//!
//! ```text
//!   dot(x, w) = n − 2 · popcount(x ⊕ w)        x, w ∈ {0,1}ⁿ ≙ {−1,+1}ⁿ
//! ```
//!
//! which turns 64 multiply-accumulates into one XOR + one `count_ones`.
//! Every layer's preactivation is an exact integer, and f32 represents
//! integers exactly up to 2²⁴ ≫ any fan-in here, so the dense ±1.0 f32
//! reference path ([`NativeModel::infer_dense`]) is *bit-identical* to the
//! packed path — the parity suite (`tests/backend_parity.rs`) and the
//! `validate` check pin that equivalence, and `benches/backend.rs`
//! measures the speedup.
//!
//! The classifier head is a synthetic binary MLP (deterministic from a
//! seed): the repo's trained export covers only the fused first layer
//! (`golden.json`), so the head stands in for the AOT backend the way
//! `FirstLayerWeights::synthetic` stands in for the golden weights.
//! Everything downstream — trait, packing, batching, parallelism — is
//! independent of where the weights come from.

use std::cell::RefCell;
use std::sync::OnceLock;

use anyhow::{ensure, Result};

use crate::config::HwConfig;
use crate::device::rng::CounterRng;
use crate::sensor::{
    pack_f32, unpack_f32, words_for, BitPlane, CaptureMode, FirstLayerWeights,
    Frame, PixelArraySim,
};

use super::InferenceBackend;

// ---------------------------------------------------------------------------
// XNOR-popcount inner kernel (runtime SIMD dispatch)
// ---------------------------------------------------------------------------

/// Function-pointer type for the XNOR-popcount inner kernel, so the
/// batched forward can be instantiated once per kernel flavour.
type XnorFn = fn(&[u64], &[u64]) -> u32;

/// Popcount of `a ⊕ b` over the common prefix of the two word slices —
/// the one inner loop every binary dot product in the model reduces to.
///
/// Dispatches once per process to the widest kernel this CPU supports
/// (AVX2 on x86-64, NEON on aarch64, scalar anywhere else).  Popcount is
/// an exact integer operation, so every kernel returns bit-identical
/// results; [`xor_popcount_scalar`] is the pinned reference and the
/// parity suite compares the two on random inputs.
#[inline]
pub fn xor_popcount(a: &[u64], b: &[u64]) -> u32 {
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { avx2::xor_popcount(a, b) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { neon::xor_popcount(a, b) },
        Kernel::Scalar => xor_popcount_scalar(a, b),
    }
}

/// Portable reference kernel: one XOR + `count_ones` per `u64` lane.
#[inline]
pub fn xor_popcount_scalar(a: &[u64], b: &[u64]) -> u32 {
    let mut differing = 0u32;
    for (&xw, &ww) in a.iter().zip(b.iter()) {
        differing += (xw ^ ww).count_ones();
    }
    differing
}

/// Name of the kernel [`xor_popcount`] dispatches to on this CPU
/// (`"avx2"`, `"neon"`, or `"scalar"`) — for banners and bench records.
pub fn active_simd() -> &'static str {
    match kernel() {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => "avx2",
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => "neon",
        Kernel::Scalar => "scalar",
    }
}

#[derive(Debug, Clone, Copy)]
enum Kernel {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

fn kernel() -> Kernel {
    static KERNEL: OnceLock<Kernel> = OnceLock::new();
    *KERNEL.get_or_init(detect_kernel)
}

#[cfg(target_arch = "x86_64")]
fn detect_kernel() -> Kernel {
    if std::arch::is_x86_feature_detected!("avx2") {
        Kernel::Avx2
    } else {
        Kernel::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_kernel() -> Kernel {
    // NEON is a mandatory part of the AArch64 baseline — no probe needed.
    Kernel::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_kernel() -> Kernel {
    Kernel::Scalar
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 XNOR-popcount: Muła's nibble-LUT popcount over 256-bit lanes.
    //!
    //! Each iteration XORs four `u64` words at once, splits the 32 bytes
    //! into low/high nibbles, looks both up in a per-nibble popcount table
    //! with `_mm256_shuffle_epi8`, and horizontally sums the byte counts
    //! into four `u64` lanes with `_mm256_sad_epu8`.  Byte counts peak at
    //! 8 and lane sums at 64 per iteration, so nothing can overflow.

    use std::arch::x86_64::*;

    /// # Safety
    ///
    /// Caller must ensure the CPU supports AVX2; the runtime dispatcher
    /// (`super::kernel`) only selects this after feature detection.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn xor_popcount(a: &[u64], b: &[u64]) -> u32 {
        let n = a.len().min(b.len());
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low_mask = _mm256_set1_epi8(0x0f);
        let zero = _mm256_setzero_si256();
        let mut acc = zero;
        let chunks = n / 4;
        for c in 0..chunks {
            let pa = a.as_ptr().add(c * 4) as *const __m256i;
            let pb = b.as_ptr().add(c * 4) as *const __m256i;
            let x = _mm256_xor_si256(_mm256_loadu_si256(pa), _mm256_loadu_si256(pb));
            let lo = _mm256_and_si256(x, low_mask);
            let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(x), low_mask);
            let cnt = _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
        }
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut total = (lanes[0] + lanes[1] + lanes[2] + lanes[3]) as u32;
        for i in chunks * 4..n {
            total += (a[i] ^ b[i]).count_ones();
        }
        total
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON XNOR-popcount: `vcntq_u8` per-byte popcount over 128-bit
    //! lanes, horizontally summed with the widening `vaddlvq_u8`.

    use std::arch::aarch64::*;

    /// # Safety
    ///
    /// NEON is part of the AArch64 baseline, so this is always callable
    /// on aarch64; the `unsafe fn` mirrors the AVX2 kernel's shape.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn xor_popcount(a: &[u64], b: &[u64]) -> u32 {
        let n = a.len().min(b.len());
        let mut total = 0u32;
        let chunks = n / 2;
        for c in 0..chunks {
            let va = vld1q_u64(a.as_ptr().add(c * 2));
            let vb = vld1q_u64(b.as_ptr().add(c * 2));
            let bytes = vcntq_u8(vreinterpretq_u8_u64(veorq_u64(va, vb)));
            total += vaddlvq_u8(bytes) as u32;
        }
        for i in chunks * 2..n {
            total += (a[i] ^ b[i]).count_ones();
        }
        total
    }
}

/// Which inner-loop implementation `run_backend` uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativePath {
    /// Bit-packed XNOR-popcount lanes (the fast path, default).
    Packed,
    /// Dense ±1.0 f32 matmuls over the same weights (parity reference).
    DenseRef,
}

/// One binary dense layer: `out_features × in_features` sign weights
/// stored packed only (bit = 1 ⇔ +1 — the dense reference path decodes
/// ±1.0 on the fly rather than keeping a second multi-MB weight copy),
/// plus a per-output integer threshold for binarization.
pub struct BinaryDense {
    pub in_features: usize,
    pub out_features: usize,
    /// Words per packed row: ⌈in_features / 64⌉.
    words: usize,
    /// Packed rows, `out_features × words`.
    w_packed: Vec<u64>,
    /// Binarization threshold on the integer preactivation.
    thresh: Vec<i32>,
}

impl BinaryDense {
    /// Deterministic synthetic layer (weights ±1 uniform, small centred
    /// thresholds so outputs stay non-degenerate).
    fn synthetic(in_features: usize, out_features: usize, rng: &mut CounterRng) -> Self {
        let words = words_for(in_features);
        let mut w_packed = vec![0u64; out_features * words];
        for o in 0..out_features {
            for i in 0..in_features {
                if rng.next_uniform() < 0.5 {
                    w_packed[o * words + i / 64] |= 1u64 << (i % 64);
                }
            }
        }
        let thresh = (0..out_features)
            .map(|_| (rng.next_uniform() * 5.0) as i32 - 2)
            .collect();
        Self { in_features, out_features, words, w_packed, thresh }
    }

    /// Weight of (output `o`, input `i`) as ±1.0.
    #[inline]
    fn weight(&self, o: usize, i: usize) -> f32 {
        if (self.w_packed[o * self.words + i / 64] >> (i % 64)) & 1 == 1 {
            1.0
        } else {
            -1.0
        }
    }

    /// Integer preactivation of output `o` over packed ±1 inputs, via
    /// the dispatched SIMD [`xor_popcount`] kernel.
    #[inline]
    fn preact_packed(&self, o: usize, x: &[u64]) -> i32 {
        let row = &self.w_packed[o * self.words..(o + 1) * self.words];
        self.in_features as i32 - 2 * xor_popcount(x, row) as i32
    }

    /// Batch-major blocked forward: the weight-row loop is the *outer*
    /// loop, so each packed row is streamed from memory once and applied
    /// to every frame in the batch while hot in cache.  `x` holds
    /// `batch × ⌈in/64⌉` words, `out` holds `batch × ⌈out/64⌉` words and
    /// is fully overwritten with the binarized packed outputs.
    fn forward_block(&self, x: &[u64], batch: usize, out: &mut [u64], kern: XnorFn) {
        let wpf_in = self.words;
        let wpf_out = words_for(self.out_features);
        debug_assert_eq!(x.len(), batch * wpf_in);
        debug_assert_eq!(out.len(), batch * wpf_out);
        out.fill(0);
        for o in 0..self.out_features {
            let row = &self.w_packed[o * wpf_in..(o + 1) * wpf_in];
            let t = self.thresh[o];
            let slot = o / 64;
            let bit = 1u64 << (o % 64);
            for item in 0..batch {
                let xi = &x[item * wpf_in..(item + 1) * wpf_in];
                let pre = self.in_features as i32 - 2 * kern(xi, row) as i32;
                if pre >= t {
                    out[item * wpf_out + slot] |= bit;
                }
            }
        }
    }

    /// f32 preactivation of output `o` over dense ±1.0 inputs, via
    /// multiply-accumulate (no XNOR/popcount).  Every partial sum is an
    /// integer with |sum| ≤ in_features < 2²⁴, so this is exact and
    /// equals `preact_packed` for matching inputs.
    #[inline]
    fn preact_dense(&self, o: usize, x: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for (i, &xi) in x.iter().enumerate() {
            acc += xi * self.weight(o, i);
        }
        acc
    }
}

/// Reusable ping-pong scratch for packed inference: two `u64` buffers
/// that alternate as layer input/output.  Hand one to
/// [`NativeModel::infer_batch_words`] and steady-state inference performs
/// no heap allocation once the buffers have grown to the model's widest
/// layer.
#[derive(Debug, Default)]
pub struct InferScratch {
    a: Vec<u64>,
    b: Vec<u64>,
}

thread_local! {
    /// Per-thread scratch shared by the allocation-free entry points
    /// ([`NativeModel::infer_words`], the backend's sequential batch
    /// path).  The model never re-enters itself on one thread, so a
    /// single slot suffices.
    static INFER_SCRATCH: RefCell<InferScratch> =
        const { RefCell::new(InferScratch { a: Vec::new(), b: Vec::new() }) };
}

/// Run `f` with this thread's inference scratch.
fn with_scratch<R>(f: impl FnOnce(&mut InferScratch) -> R) -> R {
    INFER_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// The native classifier: binarized hidden layers + an affine logit head.
pub struct NativeModel {
    /// Per-frame input geometry `(channels, height, width)`.
    pub act_shape: [usize; 3],
    hidden: Vec<BinaryDense>,
    head: BinaryDense,
    head_scale: Vec<f32>,
    head_bias: Vec<f32>,
}

impl NativeModel {
    /// Deterministic synthetic model for the given activation geometry.
    pub fn synthetic(
        act_shape: [usize; 3],
        hidden_dims: &[usize],
        num_classes: usize,
        seed: u32,
    ) -> Self {
        let mut rng = CounterRng::new(seed, 91);
        let mut dims = vec![act_shape.iter().product::<usize>()];
        dims.extend_from_slice(hidden_dims);
        let hidden = dims
            .windows(2)
            .map(|d| BinaryDense::synthetic(d[0], d[1], &mut rng))
            .collect();
        let head =
            BinaryDense::synthetic(*dims.last().unwrap(), num_classes, &mut rng);
        let head_scale =
            (0..num_classes).map(|_| 0.05 + rng.next_uniform() * 0.1).collect();
        let head_bias =
            (0..num_classes).map(|_| (rng.next_uniform() - 0.5) * 0.5).collect();
        Self { act_shape, hidden, head, head_scale, head_bias }
    }

    pub fn act_elems(&self) -> usize {
        self.act_shape.iter().product()
    }

    pub fn num_classes(&self) -> usize {
        self.head.out_features
    }

    /// XNOR-popcount inference of one frame straight from its packed
    /// [`BitPlane`] words (`words_for(act_elems)` of them, zero padding
    /// lanes) — no per-frame re-pack anywhere on this path, and no heap
    /// allocation in steady state (per-thread ping-pong scratch).
    pub fn infer_words(&self, words: &[u64], logits: &mut [f32]) {
        with_scratch(|scratch| self.infer_batch_words(words, 1, logits, scratch));
    }

    /// Batched XNOR-popcount inference over `batch` frames of packed
    /// words laid out contiguously (`batch × words_for(act_elems)`),
    /// writing `batch × num_classes` logits.  Each hidden layer runs
    /// batch-major blocked ([`BinaryDense::forward_block`]) with the
    /// dispatched SIMD kernel; `scratch` is reused across calls, so
    /// steady-state inference allocates nothing.
    pub fn infer_batch_words(
        &self,
        words: &[u64],
        batch: usize,
        logits: &mut [f32],
        scratch: &mut InferScratch,
    ) {
        self.infer_batch_impl(words, batch, logits, scratch, xor_popcount);
    }

    /// Forced-scalar variant of [`Self::infer_batch_words`] — the parity
    /// suite compares it against the SIMD-dispatched kernel.
    pub fn infer_batch_words_scalar(
        &self,
        words: &[u64],
        batch: usize,
        logits: &mut [f32],
        scratch: &mut InferScratch,
    ) {
        self.infer_batch_impl(words, batch, logits, scratch, xor_popcount_scalar);
    }

    fn infer_batch_impl(
        &self,
        words: &[u64],
        batch: usize,
        logits: &mut [f32],
        scratch: &mut InferScratch,
        kern: XnorFn,
    ) {
        debug_assert_eq!(words.len(), batch * words_for(self.act_elems()));
        debug_assert_eq!(logits.len(), batch * self.num_classes());
        let mut cur = std::mem::take(&mut scratch.a);
        let mut next = std::mem::take(&mut scratch.b);
        let mut first = true;
        for layer in &self.hidden {
            next.clear();
            next.resize(batch * words_for(layer.out_features), 0);
            let src: &[u64] = if first { words } else { &cur };
            layer.forward_block(src, batch, &mut next, kern);
            std::mem::swap(&mut cur, &mut next);
            first = false;
        }
        let src: &[u64] = if first { words } else { &cur };
        let nc = self.head.out_features;
        let wpf_in = self.head.words;
        for o in 0..nc {
            let row = &self.head.w_packed[o * wpf_in..(o + 1) * wpf_in];
            let scale = self.head_scale[o];
            let bias = self.head_bias[o];
            for item in 0..batch {
                let xi = &src[item * wpf_in..(item + 1) * wpf_in];
                let pre = self.head.in_features as i32 - 2 * kern(xi, row) as i32;
                logits[item * nc + o] = pre as f32 * scale + bias;
            }
        }
        scratch.a = cur;
        scratch.b = next;
    }

    /// XNOR-popcount inference of one frame's `{0,1}` f32 activations
    /// (compat shim: packs once, then runs [`Self::infer_words`]).
    pub fn infer_packed(&self, act: &[f32], logits: &mut [f32]) {
        self.infer_words(&pack_f32(act), logits);
    }

    /// Dense ±1.0 f32 reference over the same weights (bit-identical to
    /// [`Self::infer_packed`]; see the module docs for why).
    pub fn infer_dense(&self, act: &[f32], logits: &mut [f32]) {
        let mut cur: Vec<f32> =
            act.iter().map(|&a| if a > 0.5 { 1.0 } else { -1.0 }).collect();
        for layer in &self.hidden {
            let mut next = vec![0.0f32; layer.out_features];
            for (o, slot) in next.iter_mut().enumerate() {
                *slot = if layer.preact_dense(o, &cur) >= layer.thresh[o] as f32
                {
                    1.0
                } else {
                    -1.0
                };
            }
            cur = next;
        }
        for o in 0..self.head.out_features {
            logits[o] = self.head.preact_dense(o, &cur) * self.head_scale[o]
                + self.head_bias[o];
        }
    }
}

/// Pure-Rust inference backend: sensor-sim frontend + bit-packed XNOR
/// classifier head, batch-parallel across `std::thread` workers.
pub struct NativeBackend {
    sim: PixelArraySim,
    model: NativeModel,
    workers: usize,
    path: NativePath,
}

impl NativeBackend {
    /// Hidden-layer widths of the synthetic classifier head.
    pub const DEFAULT_HIDDEN: &'static [usize] = &[256];
    /// Classes in the synthetic 10-class corpus (matches the AOT export).
    pub const DEFAULT_CLASSES: usize = 10;
    /// Default head-weight seed (any fixed value; determinism is what
    /// matters for reproducible serving).
    pub const MODEL_SEED: u32 = 0x0B17_BA5E;

    pub fn new(
        hw: HwConfig,
        weights: FirstLayerWeights,
        sensor_height: usize,
        sensor_width: usize,
        workers: usize,
    ) -> Self {
        Self::with_model_seed(
            hw,
            weights,
            sensor_height,
            sensor_width,
            workers,
            Self::MODEL_SEED,
        )
    }

    pub fn with_model_seed(
        hw: HwConfig,
        weights: FirstLayerWeights,
        sensor_height: usize,
        sensor_width: usize,
        workers: usize,
        model_seed: u32,
    ) -> Self {
        let sim = PixelArraySim::new(hw, weights);
        let (oh, ow) = sim.out_hw(sensor_height, sensor_width);
        let c_out = sim.weights.c_out;
        let model = NativeModel::synthetic(
            [c_out, oh, ow],
            Self::DEFAULT_HIDDEN,
            Self::DEFAULT_CLASSES,
            model_seed,
        );
        Self { sim, model, workers: workers.max(1), path: NativePath::Packed }
    }

    /// Switch between the packed path and the dense reference path.
    pub fn with_path(mut self, path: NativePath) -> Self {
        self.path = path;
        self
    }

    pub fn model(&self) -> &NativeModel {
        &self.model
    }

    #[inline]
    fn infer_one(&self, act: &[f32], logits: &mut [f32]) {
        match self.path {
            NativePath::Packed => self.model.infer_packed(act, logits),
            NativePath::DenseRef => self.model.infer_dense(act, logits),
        }
    }

    /// One frame from packed words: zero-copy into the XNOR kernel on the
    /// fast path; the dense reference widens per frame (parity checks).
    #[inline]
    fn infer_one_words(&self, words: &[u64], logits: &mut [f32]) {
        match self.path {
            NativePath::Packed => self.model.infer_words(words, logits),
            NativePath::DenseRef => {
                let mut dense = vec![0.0f32; self.model.act_elems()];
                unpack_f32(words, dense.len(), &mut dense);
                self.model.infer_dense(&dense, logits);
            }
        }
    }
}

impl InferenceBackend for NativeBackend {
    fn name(&self) -> &'static str {
        match self.path {
            NativePath::Packed => "native",
            NativePath::DenseRef => "native-dense",
        }
    }

    fn arch(&self) -> String {
        let mut dims = vec![self.model.act_elems()];
        dims.extend(self.model.hidden.iter().map(|l| l.out_features));
        dims.push(self.model.num_classes());
        format!(
            "xnor-mlp {}",
            dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("-")
        )
    }

    fn act_shape(&self) -> [usize; 3] {
        self.model.act_shape
    }

    fn num_classes(&self) -> usize {
        self.model.num_classes()
    }

    fn preload(&self, _batches: &[usize]) -> Result<()> {
        Ok(()) // nothing to compile: weights are resident
    }

    fn run_frontend(&self, frame: &Frame) -> Result<BitPlane> {
        let (oh, ow) = self.sim.out_hw(frame.height, frame.width);
        let [_, mh, mw] = self.model.act_shape;
        ensure!(
            (oh, ow) == (mh, mw),
            "frame {}×{} maps to {oh}×{ow} activations; backend built for {mh}×{mw}",
            frame.height,
            frame.width,
        );
        Ok(self.sim.capture(frame, CaptureMode::Ideal).0)
    }

    fn run_backend(&self, acts: &[f32], batch: usize) -> Result<Vec<f32>> {
        let elems = self.model.act_elems();
        ensure!(
            acts.len() == batch * elems,
            "activation buffer has {} elements, want batch {batch} × {elems}",
            acts.len()
        );
        let nc = self.model.num_classes();
        let mut out = vec![0.0f32; batch * nc];
        let workers = self.workers.min(batch.max(1));
        if workers <= 1 || batch <= 1 {
            for (item, logits) in acts.chunks(elems).zip(out.chunks_mut(nc)) {
                self.infer_one(item, logits);
            }
            return Ok(out);
        }
        let per = batch.div_ceil(workers);
        std::thread::scope(|s| {
            for (in_chunk, out_chunk) in
                acts.chunks(per * elems).zip(out.chunks_mut(per * nc))
            {
                let _worker = s.spawn(move || {
                    for (item, logits) in
                        in_chunk.chunks(elems).zip(out_chunk.chunks_mut(nc))
                    {
                        self.infer_one(item, logits);
                    }
                });
            }
            // handles join implicitly at scope exit
        });
        Ok(out)
    }

    fn run_backend_packed(&self, words: &[u64], batch: usize) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.run_backend_packed_into(words, batch, &mut out)?;
        Ok(out)
    }

    /// Allocation-free batch entry: logits land in the caller's buffer,
    /// layer activations in per-thread [`InferScratch`].  With one worker
    /// the whole batch runs batch-major blocked on the calling thread; in
    /// steady state (warm buffers) that path performs zero heap
    /// allocation.  With several workers each scope thread processes its
    /// chunk with its own scratch (one allocation set per thread per
    /// batch — thread spawning dominates that cost anyway).
    fn run_backend_packed_into(
        &self,
        words: &[u64],
        batch: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let elems = self.model.act_elems();
        let wpf = words_for(elems);
        ensure!(
            words.len() == batch * wpf,
            "packed buffer has {} words, want batch {batch} × {wpf}",
            words.len()
        );
        let nc = self.model.num_classes();
        out.clear();
        out.resize(batch * nc, 0.0);
        let workers = self.workers.min(batch.max(1));
        if workers <= 1 || batch <= 1 {
            match self.path {
                NativePath::Packed => with_scratch(|scratch| {
                    self.model.infer_batch_words(words, batch, out, scratch);
                }),
                NativePath::DenseRef => {
                    for (item, logits) in words.chunks(wpf).zip(out.chunks_mut(nc)) {
                        self.infer_one_words(item, logits);
                    }
                }
            }
            return Ok(());
        }
        let per = batch.div_ceil(workers);
        std::thread::scope(|s| {
            for (in_chunk, out_chunk) in
                words.chunks(per * wpf).zip(out.chunks_mut(per * nc))
            {
                let _worker = s.spawn(move || match self.path {
                    NativePath::Packed => {
                        let chunk_batch = in_chunk.len() / wpf;
                        let mut scratch = InferScratch::default();
                        self.model.infer_batch_words(
                            in_chunk,
                            chunk_batch,
                            out_chunk,
                            &mut scratch,
                        );
                    }
                    NativePath::DenseRef => {
                        for (item, logits) in
                            in_chunk.chunks(wpf).zip(out_chunk.chunks_mut(nc))
                        {
                            self.infer_one_words(item, logits);
                        }
                    }
                });
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xnor_popcount_matches_naive_dot() {
        let mut rng = CounterRng::new(3, 8);
        let layer = BinaryDense::synthetic(130, 5, &mut rng);
        // Random {0,1} input, checked against the ±1 naive dot product.
        let mut irng = CounterRng::new(9, 2);
        let act: Vec<f32> = (0..130)
            .map(|_| if irng.next_uniform() < 0.3 { 1.0 } else { 0.0 })
            .collect();
        let packed = pack_f32(&act);
        let pm: Vec<f32> =
            act.iter().map(|&a| if a > 0.5 { 1.0 } else { -1.0 }).collect();
        for o in 0..5 {
            let naive: i32 = (0..130)
                .map(|i| {
                    let x = if act[i] > 0.5 { 1i32 } else { -1 };
                    x * layer.weight(o, i) as i32
                })
                .sum();
            assert_eq!(layer.preact_packed(o, &packed), naive, "output {o}");
            assert_eq!(layer.preact_dense(o, &pm) as i32, naive);
        }
    }

    #[test]
    fn packed_and_dense_paths_bit_identical() {
        let model = NativeModel::synthetic([8, 5, 5], &[64, 32], 10, 11);
        let mut rng = CounterRng::new(21, 4);
        for trial in 0..10 {
            let act: Vec<f32> = (0..model.act_elems())
                .map(|_| if rng.next_uniform() < 0.25 { 1.0 } else { 0.0 })
                .collect();
            let mut a = vec![0.0f32; 10];
            let mut b = vec![0.0f32; 10];
            let mut c = vec![0.0f32; 10];
            model.infer_packed(&act, &mut a);
            model.infer_dense(&act, &mut b);
            model.infer_words(&pack_f32(&act), &mut c);
            assert_eq!(a, b, "trial {trial}");
            assert_eq!(a, c, "trial {trial} (words entry)");
        }
    }

    #[test]
    fn run_backend_packed_matches_f32_entry_across_workers() {
        let hw = HwConfig::default();
        let w = FirstLayerWeights::synthetic(16, 3, 3, 5);
        let b1 = NativeBackend::new(hw.clone(), w.clone(), 20, 20, 1);
        let b4 = NativeBackend::new(hw.clone(), w.clone(), 20, 20, 4);
        let dense_ref = NativeBackend::new(hw, w, 20, 20, 2)
            .with_path(NativePath::DenseRef);
        let elems = b1.act_elems();
        let wpf = words_for(elems);
        let batch = 5usize;
        let mut rng = CounterRng::new(17, 9);
        let acts: Vec<f32> = (0..batch * elems)
            .map(|_| if rng.next_uniform() < 0.2 { 1.0 } else { 0.0 })
            .collect();
        let mut packed = Vec::with_capacity(batch * wpf);
        for frame in acts.chunks(elems) {
            packed.extend(pack_f32(frame));
        }
        let via_f32 = b1.run_backend(&acts, batch).unwrap();
        let via_words_seq = b1.run_backend_packed(&packed, batch).unwrap();
        let via_words_par = b4.run_backend_packed(&packed, batch).unwrap();
        let via_dense = dense_ref.run_backend_packed(&packed, batch).unwrap();
        assert_eq!(via_f32, via_words_seq);
        assert_eq!(via_f32, via_words_par);
        assert_eq!(via_f32, via_dense, "dense-ref packed entry must agree");
        assert!(b1.run_backend_packed(&packed[1..], batch).is_err());
    }

    #[test]
    fn backend_shapes_and_determinism() {
        let hw = HwConfig::default();
        let w = FirstLayerWeights::synthetic(32, 3, 3, 2);
        let backend = NativeBackend::new(hw, w, 32, 32, 2);
        assert_eq!(backend.act_shape(), [32, 15, 15]);
        assert_eq!(backend.num_classes(), 10);
        assert!(backend.arch().starts_with("xnor-mlp"));
        let act = vec![0.0f32; backend.act_elems()];
        let x = backend.run_backend(&act, 1).unwrap();
        let y = backend.run_backend(&act, 1).unwrap();
        assert_eq!(x, y);
        assert_eq!(x.len(), 10);
    }

    #[test]
    fn batched_equals_sequential_across_worker_counts() {
        let hw = HwConfig::default();
        let w = FirstLayerWeights::synthetic(16, 3, 3, 5);
        let mut rng = CounterRng::new(33, 6);
        let b1 = NativeBackend::new(hw.clone(), w.clone(), 20, 20, 1);
        let b4 = NativeBackend::new(hw, w, 20, 20, 4);
        let elems = b1.act_elems();
        let batch = 7usize;
        let acts: Vec<f32> = (0..batch * elems)
            .map(|_| if rng.next_uniform() < 0.2 { 1.0 } else { 0.0 })
            .collect();
        let seq = b1.run_backend(&acts, batch).unwrap();
        let par = b4.run_backend(&acts, batch).unwrap();
        assert_eq!(seq, par);
    }

    #[test]
    fn run_backend_rejects_bad_lengths() {
        let hw = HwConfig::default();
        let w = FirstLayerWeights::synthetic(8, 3, 3, 1);
        let backend = NativeBackend::new(hw, w, 16, 16, 1);
        assert!(backend.run_backend(&[0.0; 3], 1).is_err());
    }

    #[test]
    fn simd_kernel_matches_scalar_on_all_lengths() {
        // Lengths straddle every SIMD block boundary (AVX2 consumes 4
        // words/iter, NEON 2) plus odd tails and the empty slice.
        let mut rng = CounterRng::new(77, 3);
        let mut word = || {
            let hi = (rng.next_uniform() * 4_294_967_296.0) as u64;
            let lo = (rng.next_uniform() * 4_294_967_296.0) as u64;
            (hi << 32) | lo
        };
        for len in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 33, 64, 129] {
            let a: Vec<u64> = (0..len).map(|_| word()).collect();
            let b: Vec<u64> = (0..len).map(|_| word()).collect();
            assert_eq!(
                xor_popcount(&a, &b),
                xor_popcount_scalar(&a, &b),
                "len {len} (kernel {})",
                active_simd()
            );
        }
        assert!(["avx2", "neon", "scalar"].contains(&active_simd()));
    }

    #[test]
    fn batched_words_match_per_frame_and_scalar_kernel() {
        let model = NativeModel::synthetic([8, 5, 5], &[64, 32], 10, 11);
        let wpf = words_for(model.act_elems());
        let nc = model.num_classes();
        let batch = 6usize;
        let mut rng = CounterRng::new(41, 7);
        let mut words = Vec::with_capacity(batch * wpf);
        let mut expect = vec![0.0f32; batch * nc];
        for item in 0..batch {
            let act: Vec<f32> = (0..model.act_elems())
                .map(|_| if rng.next_uniform() < 0.3 { 1.0 } else { 0.0 })
                .collect();
            let packed = pack_f32(&act);
            model.infer_words(&packed, &mut expect[item * nc..(item + 1) * nc]);
            words.extend(packed);
        }
        let mut scratch = InferScratch::default();
        let mut got = vec![0.0f32; batch * nc];
        model.infer_batch_words(&words, batch, &mut got, &mut scratch);
        assert_eq!(got, expect, "batched vs per-frame");
        let mut scalar = vec![0.0f32; batch * nc];
        model.infer_batch_words_scalar(&words, batch, &mut scalar, &mut scratch);
        assert_eq!(scalar, expect, "forced-scalar kernel vs dispatched");
    }

    #[test]
    fn packed_into_reuses_buffer_and_matches_vec_entry() {
        let hw = HwConfig::default();
        let w = FirstLayerWeights::synthetic(16, 3, 3, 5);
        let backend = NativeBackend::new(hw, w, 20, 20, 1);
        let wpf = words_for(backend.act_elems());
        let batch = 3usize;
        let mut rng = CounterRng::new(55, 2);
        let words: Vec<u64> = (0..batch * wpf)
            .map(|_| (rng.next_uniform() * u32::MAX as f64) as u64)
            .collect();
        let via_vec = backend.run_backend_packed(&words, batch).unwrap();
        let mut out = Vec::new();
        backend.run_backend_packed_into(&words, batch, &mut out).unwrap();
        assert_eq!(out, via_vec);
        // Second call must reuse the buffer (same capacity, fresh fill).
        backend.run_backend_packed_into(&words, batch, &mut out).unwrap();
        assert_eq!(out, via_vec);
        assert!(backend
            .run_backend_packed_into(&words[1..], batch, &mut out)
            .is_err());
    }
}
