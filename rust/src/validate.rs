//! Artifact validation: cross-checks the rust sensor simulator and the
//! native backend against the golden vectors exported by `aot.py`, and —
//! when built with the `pjrt` feature — executes every AOT executable
//! against the same oracle.  This is the cross-language correctness gate
//! (`pixelmtj validate`, also exercised by `rust/tests/golden.rs`).

use anyhow::{bail, Context, Result};
use std::fmt::Write as _;
use std::path::Path;

use crate::backend::{InferenceBackend, NativeBackend, NativePath};
use crate::config::{ArtifactMeta, HwConfig};
use crate::sensor::{CaptureMode, FirstLayerWeights, Frame, PixelArraySim};
use crate::util::json::Value;

/// Result of one validation check.
#[derive(Debug)]
pub struct Check {
    pub name: &'static str,
    pub pass: bool,
    pub detail: String,
}

/// Run all checks; `Ok(report)` even when individual checks fail — the
/// report text carries pass/fail per check; errors are reserved for
/// missing artifacts.
pub fn run(artifacts_dir: &Path) -> Result<String> {
    let checks = run_checks(artifacts_dir)?;
    let mut out = String::new();
    let mut all = true;
    for c in &checks {
        all &= c.pass;
        let _ = writeln!(
            out,
            "[{}] {:<38} {}",
            if c.pass { "PASS" } else { "FAIL" },
            c.name,
            c.detail
        );
    }
    let _ = writeln!(
        out,
        "{} ({}/{} checks passed)",
        if all { "VALID" } else { "INVALID" },
        checks.iter().filter(|c| c.pass).count(),
        checks.len()
    );
    if !all {
        bail!("artifact validation failed:\n{out}");
    }
    Ok(out)
}

pub fn run_checks(artifacts_dir: &Path) -> Result<Vec<Check>> {
    // Native checks first, and a PJRT construction failure becomes a
    // failing *check* rather than an abort — the pure-Rust half of the
    // report must survive a broken/stubbed runtime.  Hard errors are
    // reserved for missing artifacts.
    #[allow(unused_mut)]
    let mut checks = native_checks(artifacts_dir)?;
    #[cfg(feature = "pjrt")]
    match pjrt_checks(artifacts_dir) {
        Ok(more) => checks.extend(more),
        Err(e) => checks.push(Check {
            name: "pjrt runtime constructs",
            pass: false,
            detail: format!("{e:#}"),
        }),
    }
    Ok(checks)
}

/// Checks that need only the golden vectors + the pure-Rust stack.
fn native_checks(artifacts_dir: &Path) -> Result<Vec<Check>> {
    let golden = Value::from_file(&artifacts_dir.join("golden.json"))
        .context("golden.json missing — run `make artifacts`")?;
    let meta = ArtifactMeta::from_dir(artifacts_dir)?;

    let img = golden.get("img")?.as_f32_vec()?;
    let want_front = golden.get("frontend_out")?.as_f32_vec()?;
    let want_mtj = golden.get("frontend_mtj_out")?.as_f32_vec()?;
    let mtj_seed = golden.get("mtj_seed")?.as_u32()?;

    let mut checks = Vec::new();

    // 1. Rust sensor simulator agrees with the Python oracle's ideal bits.
    let hw = HwConfig::from_json_file(artifacts_dir.join("hwcfg.json"))?;
    let weights =
        FirstLayerWeights::from_golden(artifacts_dir.join("golden.json"))?;
    let sim = PixelArraySim::new(hw.clone(), weights.clone());
    let frame = Frame::from_data(
        meta.img_shape[1],
        meta.img_shape[2],
        meta.img_shape[3],
        img.clone(),
        mtj_seed,
    )?;
    let (map, _) = sim.capture(&frame, CaptureMode::Ideal);
    let bools = map.to_bools();
    let agree = bools
        .iter()
        .zip(want_front.iter())
        .filter(|(&b, &w)| (b as u8 as f32) == w)
        .count();
    let rate = agree as f64 / want_front.len() as f64;
    checks.push(Check {
        name: "rust sensor sim vs golden frontend",
        pass: rate >= 0.995,
        detail: format!("{:.3} % bit agreement", rate * 100.0),
    });

    // 2. Rust stochastic capture agrees with the seeded oracle draw-for-
    //    draw wherever the ideal bits agree (the RNG must match exactly).
    let (map_mtj, _) = sim.capture(&frame, CaptureMode::CalibratedMtj);
    let mut mismatched_draws = 0usize;
    let mut comparable = 0usize;
    for (i, &b) in bools.iter().enumerate() {
        if (b as u8 as f32) == want_front[i] {
            comparable += 1;
            if (map_mtj.get(i) as u8 as f32) != want_mtj[i] {
                mismatched_draws += 1;
            }
        }
    }
    checks.push(Check {
        name: "rust MTJ draws vs pallas kernel",
        pass: mismatched_draws == 0,
        detail: format!(
            "{mismatched_draws}/{comparable} comparable sites differ"
        ),
    });

    // 3. hwcfg.json matches the rust defaults (single source of truth).
    checks.push(Check {
        name: "hwcfg.json = rust defaults",
        pass: hw == HwConfig::default(),
        detail: String::new(),
    });

    // 4. Native backend: the XNOR-popcount path must be bit-identical to
    //    its dense f32 reference on the golden first-layer activations.
    let (h, w) = (frame.height, frame.width);
    let packed =
        NativeBackend::new(hw.clone(), weights.clone(), h, w, 1);
    let dense = NativeBackend::new(hw, weights, h, w, 1)
        .with_path(NativePath::DenseRef);
    let act = map.to_f32();
    let lp = packed.run_backend(&act, 1)?;
    let ld = dense.run_backend(&act, 1)?;
    let max_err = max_abs_diff(&lp, &ld);
    checks.push(Check {
        name: "native packed vs dense reference",
        pass: lp == ld,
        detail: format!("max |Δ| = {max_err:.2e}"),
    });

    Ok(checks)
}

/// Checks that execute the AOT artifacts through PJRT.
#[cfg(feature = "pjrt")]
fn pjrt_checks(artifacts_dir: &Path) -> Result<Vec<Check>> {
    use crate::runtime::{u32_scalar, Runtime};

    let golden = Value::from_file(&artifacts_dir.join("golden.json"))
        .context("golden.json missing — run `make artifacts`")?;
    let runtime = Runtime::cpu(artifacts_dir)?;
    let meta = runtime
        .meta
        .as_ref()
        .context("meta.json missing — run `make artifacts`")?
        .clone();

    let img = golden.get("img")?.as_f32_vec()?;
    let want_front = golden.get("frontend_out")?.as_f32_vec()?;
    let want_mtj = golden.get("frontend_mtj_out")?.as_f32_vec()?;
    let want_logits = golden.get("logits")?.as_f32_vec()?;
    let mtj_seed = golden.get("mtj_seed")?.as_u32()?;
    let img_shape: Vec<i64> =
        meta.img_shape.iter().map(|&d| d as i64).collect();
    let act_shape: Vec<i64> =
        meta.act_shape.iter().map(|&d| d as i64).collect();

    let mut checks = Vec::new();

    // AOT frontend (ideal comparator) reproduces the oracle bits.
    let front = runtime.load("frontend_b1")?;
    let got = &front.run_f32(&[(&img, &img_shape)])?[0];
    let diff = count_diff(got, &want_front);
    checks.push(Check {
        name: "frontend_b1 vs oracle",
        pass: diff == 0,
        detail: format!("{diff}/{} bits differ", want_front.len()),
    });

    // AOT stochastic frontend reproduces the oracle draw-for-draw.
    let front_mtj = runtime.load("frontend_mtj_b1")?;
    let img_lit = xla::Literal::vec1(&img).reshape(&img_shape)?;
    let got_mtj =
        &front_mtj.run_literals(&[img_lit, u32_scalar(mtj_seed)])?[0];
    let diff = count_diff(got_mtj, &want_mtj);
    checks.push(Check {
        name: "frontend_mtj_b1 vs oracle (seeded)",
        pass: diff == 0,
        detail: format!("{diff}/{} bits differ", want_mtj.len()),
    });

    // Backend logits.
    let backend = runtime.load("backend_b1")?;
    let got_logits = &backend.run_f32(&[(&want_front, &act_shape)])?[0];
    let max_err = max_abs_diff(got_logits, &want_logits);
    checks.push(Check {
        name: "backend_b1 logits vs oracle",
        pass: max_err < 1e-3,
        detail: format!("max |Δ| = {max_err:.2e}"),
    });

    // Fused full model agrees with frontend∘backend.
    let full = runtime.load("full_b1")?;
    let got_full = &full.run_f32(&[(&img, &img_shape)])?[0];
    let max_err_full = max_abs_diff(got_full, &want_logits);
    checks.push(Check {
        name: "full_b1 vs composed stages",
        pass: max_err_full < 1e-3,
        detail: format!("max |Δ| = {max_err_full:.2e}"),
    });

    Ok(checks)
}

#[cfg(feature = "pjrt")]
fn count_diff(a: &[f32], b: &[f32]) -> usize {
    a.iter().zip(b.iter()).filter(|(x, y)| x != y).count()
        + a.len().abs_diff(b.len())
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}
