//! Weight-augmented 3T pixel circuit (paper §2.2.1, Fig. 3b).
//!
//! Behavioural model of the GF22FDX pixel: the photodiode discharges node
//! N proportionally to light intensity; transistor M1's current is
//! modulated by both the gate voltage (intensity) and the source-
//! degenerating weight transistor (width ∝ |weight|); bitline-shared
//! pixels sum currents to produce the analog MAC.  The net transfer from
//! normalized `Σ w·x` to the bitline voltage is the Fig. 4(a) curve —
//! unit slope at the origin with compressive saturation — identical,
//! constant-for-constant, to `kernels/ref.py::fitted_nonlinearity`.

use crate::config::CircuitConfig;
use crate::device::rng::CounterRng;

/// Fig. 4(a) transfer curve: `f(x) = (1-α)·x + α·S·tanh(x/S)`.
#[inline]
pub fn fitted_nonlinearity(x: f64, cfg: &CircuitConfig) -> f64 {
    (1.0 - cfg.nl_alpha) * x + cfg.nl_alpha * cfg.nl_sat * (x / cfg.nl_sat).tanh()
}

/// Map a normalized MAC value in `[-mac_range, mac_range]` to the bitline
/// voltage in `[0, VDD]` (the paper's "voltage range … linearly mapped to
/// the algorithmic normalized range of [-3, 3]").
#[inline]
pub fn norm_to_volt(x: f64, cfg: &CircuitConfig) -> f64 {
    cfg.vdd * 0.5 + x / cfg.mac_range * (cfg.vdd * 0.5)
}

/// Inverse of [`norm_to_volt`].
#[inline]
pub fn volt_to_norm(v: f64, cfg: &CircuitConfig) -> f64 {
    (v - cfg.vdd * 0.5) / (cfg.vdd * 0.5) * cfg.mac_range
}

/// One photodiode integration: node-N voltage after `t_us` of exposure to
/// `intensity ∈ [0, 1]`.  Discharge is linear in intensity·time until the
/// node saturates near ground (photodiode current is light-linear; the
/// 5 µs integration window is sized to stay in the linear region).
pub fn photodiode_discharge(
    intensity: f64,
    t_us: f64,
    cfg: &CircuitConfig,
) -> f64 {
    let full_scale_us = cfg.integration_time_us; // calibrated full range
    let drop = cfg.vdd * (intensity.clamp(0.0, 1.0) * t_us / full_scale_us);
    (cfg.vdd - drop).max(0.0)
}

/// The shared-bitline MAC of one kernel position for one weight polarity.
///
/// `inputs` are normalized light intensities in `[0, 1]`; `weights` are the
/// *magnitudes* of the same-polarity weights (the other polarity's phase
/// runs separately, per the two-phase scheme).  Returns the normalized
/// post-nonlinearity MAC (the algorithmic value the subtractor sees).
pub fn pixel_mac(
    inputs: &[f64],
    weights: &[f64],
    cfg: &CircuitConfig,
    noise: Option<&mut CounterRng>,
) -> f64 {
    debug_assert_eq!(inputs.len(), weights.len());
    let mac: f64 = inputs
        .iter()
        .zip(weights.iter())
        .map(|(x, w)| x * w)
        .sum();
    let mut out = fitted_nonlinearity(mac, cfg);
    if let Some(rng) = noise {
        out += cfg.analog_noise_sigma * rng.next_normal() as f64;
    }
    out
}

/// Fig. 4(a) regenerator: sweep (weight, intensity) combinations for a
/// 3×3×3 kernel and report (ideal W·I, simulated normalized output) pairs.
pub fn fig4a_scatter(
    cfg: &CircuitConfig,
    n_points: usize,
    seed: u32,
) -> Vec<(f64, f64)> {
    let mut rng = CounterRng::new(seed, 40);
    let mut pts = Vec::with_capacity(n_points);
    for _ in 0..n_points {
        // 27 pixels with random intensities and signed weights such that
        // the ideal MAC spans the paper's [-3, 3] plot range.
        let mut ideal = 0.0;
        let mut inputs = [0.0; 27];
        let mut weights = [0.0; 27];
        for i in 0..27 {
            inputs[i] = rng.next_uniform() as f64;
            weights[i] = (rng.next_uniform() as f64 - 0.5) * 2.0 * 0.45;
            ideal += inputs[i] * weights[i];
        }
        // Two-phase simulated output (pos and neg phases subtracted).
        let wp: Vec<f64> = weights.iter().map(|w| w.max(0.0)).collect();
        let wn: Vec<f64> = weights.iter().map(|w| (-w).max(0.0)).collect();
        let vp = pixel_mac(&inputs, &wp, cfg, None);
        let vn = pixel_mac(&inputs, &wn, cfg, None);
        let mut noise = CounterRng::new(seed ^ 0xF16_4A, 41);
        let sim =
            vp - vn + cfg.analog_noise_sigma * noise.next_normal() as f64;
        pts.push((ideal, sim));
    }
    pts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CircuitConfig;

    fn cfg() -> CircuitConfig {
        CircuitConfig::default()
    }

    #[test]
    fn nonlinearity_matches_python_constants() {
        let c = cfg();
        // f(1.0) with α=0.35, S=3: 0.65 + 1.05·tanh(1/3)
        let want = 0.65 + 0.35 * 3.0 * (1.0f64 / 3.0).tanh();
        assert!((fitted_nonlinearity(1.0, &c) - want).abs() < 1e-12);
    }

    #[test]
    fn nonlinearity_odd_symmetric() {
        let c = cfg();
        for x in [-2.5, -1.0, 0.3, 2.2] {
            let f = fitted_nonlinearity(x, &c);
            let g = fitted_nonlinearity(-x, &c);
            assert!((f + g).abs() < 1e-12);
        }
    }

    #[test]
    fn volt_mapping_roundtrip_and_rails() {
        let c = cfg();
        assert!((norm_to_volt(0.0, &c) - c.vdd / 2.0).abs() < 1e-12);
        assert!((norm_to_volt(c.mac_range, &c) - c.vdd).abs() < 1e-12);
        assert!(norm_to_volt(-c.mac_range, &c).abs() < 1e-12);
        for x in [-2.9, -0.4, 0.0, 1.7] {
            assert!((volt_to_norm(norm_to_volt(x, &c), &c) - x).abs() < 1e-9);
        }
    }

    #[test]
    fn photodiode_dark_stays_at_vdd() {
        let c = cfg();
        assert!((photodiode_discharge(0.0, 5.0, &c) - c.vdd).abs() < 1e-12);
    }

    #[test]
    fn photodiode_bright_discharges_fully() {
        let c = cfg();
        assert!(photodiode_discharge(1.0, 5.0, &c) < 1e-9);
    }

    #[test]
    fn photodiode_monotone_in_intensity() {
        let c = cfg();
        let mut prev = f64::MAX;
        for i in 0..=10 {
            let v = photodiode_discharge(i as f64 / 10.0, 5.0, &c);
            assert!(v <= prev);
            prev = v;
        }
    }

    #[test]
    fn pixel_mac_matches_closed_form() {
        let c = cfg();
        let inputs = [0.5, 1.0, 0.0];
        let weights = [0.2, 0.4, 0.9];
        let mac = 0.5 * 0.2 + 1.0 * 0.4;
        let want = fitted_nonlinearity(mac, &c);
        assert!((pixel_mac(&inputs, &weights, &c, None) - want).abs() < 1e-12);
    }

    #[test]
    fn noise_perturbs_output() {
        let c = cfg();
        let inputs = [0.5; 27];
        let weights = [0.1; 27];
        let clean = pixel_mac(&inputs, &weights, &c, None);
        let mut rng = CounterRng::new(3, 9);
        let noisy = pixel_mac(&inputs, &weights, &c, Some(&mut rng));
        assert!((clean - noisy).abs() > 0.0);
        assert!((clean - noisy).abs() < 10.0 * c.analog_noise_sigma);
    }

    #[test]
    fn fig4a_tracks_ideal_line() {
        let c = cfg();
        let pts = fig4a_scatter(&c, 500, 1);
        // The simulated output must track the ideal with bounded deviation
        // (compressive near the rails, tight near the origin).
        for &(ideal, sim) in &pts {
            assert!(
                (sim - ideal).abs() <= 0.12 * ideal.abs().max(1.0) + 0.05,
                "({ideal}, {sim}) off the Fig. 4a band"
            );
        }
        // And correlation is near-perfect.
        let n = pts.len() as f64;
        let (mx, my): (f64, f64) = (
            pts.iter().map(|p| p.0).sum::<f64>() / n,
            pts.iter().map(|p| p.1).sum::<f64>() / n,
        );
        let cov: f64 =
            pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum::<f64>() / n;
        let (vx, vy): (f64, f64) = (
            pts.iter().map(|p| (p.0 - mx).powi(2)).sum::<f64>() / n,
            pts.iter().map(|p| (p.1 - my).powi(2)).sum::<f64>() / n,
        );
        let r = cov / (vx * vy).sqrt();
        assert!(r > 0.99, "correlation {r}");
    }
}
