//! Burst-mode read path: MUX + comparator + reset pulse generator
//! (paper §2.2.4, Figs. 3f-h, 6).
//!
//! During the read phase the source line carries the (reverse-polarity,
//! disturb-free) read voltage; the MUX selects one MTJ at a time; the
//! comparator senses the divider voltage against `V_REF` placed between
//! the P and AP sense levels.  A parallel-state device produces a spike
//! (`O_ACT`); a reset pulse follows for any device found parallel.

use crate::config::{CircuitConfig, MtjConfig};
use crate::device::mtj::{MtjModel, MtjState};
use crate::device::neuron::MultiMtjNeuron;

/// Sense-path parameters shared by every kernel's readout.
#[derive(Debug, Clone)]
pub struct SensePath {
    /// Source-line load resistance (Ω).
    pub r_load: f64,
    /// Comparator threshold (V).
    pub v_ref: f64,
}

impl SensePath {
    /// Place `V_REF` a configured fraction of the way between the AP and P
    /// sense levels (paper: "narrow sense margin" ⇒ sequential reads).
    pub fn new(model: &MtjModel, circuit: &CircuitConfig) -> Self {
        let mcfg = model.cfg();
        // Load chosen near the geometric mean of R_P and R_AP to maximize
        // the divider swing.
        let rap = model.resistance(MtjState::AntiParallel, mcfg.read_voltage);
        let r_load = (mcfg.r_p_ohm * rap).sqrt();
        let v_p = mcfg.read_voltage * r_load / (mcfg.r_p_ohm + r_load);
        let v_ap = mcfg.read_voltage * r_load / (rap + r_load);
        let v_ref = v_ap + circuit.comparator_vref_frac * (v_p - v_ap);
        Self { r_load, v_ref }
    }

    /// Absolute sense margin (V) between the two states.
    pub fn sense_margin(&self, model: &MtjModel) -> f64 {
        let mcfg = model.cfg();
        let rap = model.resistance(MtjState::AntiParallel, mcfg.read_voltage);
        let v_p = mcfg.read_voltage * self.r_load / (mcfg.r_p_ohm + self.r_load);
        let v_ap = mcfg.read_voltage * self.r_load / (rap + self.r_load);
        v_p - v_ap
    }
}

/// One step of the Fig. 6 burst-read trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BurstReadStep {
    /// Time of the read pulse (ns from burst start).
    pub t_ns: f64,
    /// Device index within the neuron.
    pub device: usize,
    /// Comparator input voltage (V_MTJ in Fig. 6).
    pub v_mtj: f64,
    /// Comparator output: activation spike present.
    pub spike: bool,
    /// Whether a reset pulse was issued after this read.
    pub reset_issued: bool,
}

/// Result of burst-reading one multi-MTJ neuron.
#[derive(Debug, Clone)]
pub struct BurstReadResult {
    pub steps: Vec<BurstReadStep>,
    /// Majority-vote activation (≥ k spikes).
    pub activation: bool,
    /// Total reset pulses issued.
    pub reset_pulses: usize,
    /// Total burst duration (ns).
    pub duration_ns: f64,
}

/// Burst-read engine: sequential read (+ conditional reset) of one neuron.
#[derive(Debug, Clone)]
pub struct BurstReader {
    pub sense: SensePath,
    mtj_cfg: MtjConfig,
    majority_k: usize,
}

impl BurstReader {
    pub fn new(model: &MtjModel, circuit: &CircuitConfig) -> Self {
        Self {
            sense: SensePath::new(model, circuit),
            mtj_cfg: model.cfg().clone(),
            majority_k: model.cfg().majority_k,
        }
    }

    /// Read every device, majority-vote, and reset the switched ones
    /// (paper: read first, then reset the devices found parallel).
    pub fn read_and_reset(
        &self,
        model: &MtjModel,
        neuron: &mut MultiMtjNeuron,
        seed: u32,
        index: u32,
    ) -> BurstReadResult {
        let mut steps = Vec::with_capacity(neuron.n());
        let mut spikes = 0usize;
        let mut t = 0.0f64;
        let read_w = self.mtj_cfg.read_pulse_ns;
        let reset_w = self.mtj_cfg.reset_pulse_ns;
        let mut reset_pulses = 0usize;

        // Phase 1: sequential reads through the MUX.
        let mut fired = vec![false; neuron.n()];
        for (m, dev) in neuron.devices().iter().enumerate() {
            let sample = dev.read(model, self.sense.r_load);
            debug_assert!(!sample.disturbed);
            let spike = sample.v_sense > self.sense.v_ref;
            fired[m] = spike;
            spikes += spike as usize;
            steps.push(BurstReadStep {
                t_ns: t,
                device: m,
                v_mtj: sample.v_sense,
                spike,
                reset_issued: false,
            });
            t += read_w;
        }

        // Phase 2: conditional iterative reset of switched devices.
        let before = t;
        let pulses = neuron.reset_all(model, seed, index, 16);
        reset_pulses += pulses;
        t += pulses as f64 * reset_w;
        for (step, &f) in steps.iter_mut().zip(fired.iter()) {
            step.reset_issued = f;
        }
        let _ = before;

        BurstReadResult {
            steps,
            activation: spikes >= self.majority_k,
            reset_pulses,
            duration_ns: t,
        }
    }

    /// Fig. 6 regenerator: trace the burst read of a neuron prepared in an
    /// explicit device-state pattern (e.g. P-P-AP-AP-P-P-AP-P).
    pub fn trace_pattern(
        &self,
        model: &MtjModel,
        pattern: &[MtjState],
    ) -> BurstReadResult {
        let mut neuron = MultiMtjNeuron::new(pattern.len());
        for (m, &s) in pattern.iter().enumerate() {
            // Safe: test/trace-only setup accessor.
            neuron_set_state(&mut neuron, m, s);
        }
        self.read_and_reset(model, &mut neuron, 0, 0)
    }
}

/// Internal helper to prepare explicit device patterns for traces.
fn neuron_set_state(neuron: &mut MultiMtjNeuron, idx: usize, s: MtjState) {
    // MultiMtjNeuron exposes devices immutably; reconstruct via write path.
    // For trace purposes we rebuild using the unsafe-free approach below.
    let n = neuron.n();
    debug_assert!(idx < n);
    // Reach in through a controlled accessor.
    neuron.set_device_state(idx, s);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CircuitConfig, MtjConfig};

    fn setup() -> (MtjModel, CircuitConfig) {
        (MtjModel::new(&MtjConfig::default()), CircuitConfig::default())
    }

    #[test]
    fn sense_path_has_positive_margin() {
        let (m, c) = setup();
        let sp = SensePath::new(&m, &c);
        assert!(sp.sense_margin(&m) > 0.01, "margin {}", sp.sense_margin(&m));
        assert!(sp.v_ref > 0.0 && sp.v_ref < m.cfg().read_voltage);
    }

    #[test]
    fn fig6_pattern_reproduces_paper_sequence() {
        // Paper Fig. 6: neuron states P-P-AP-AP-P-P-AP-P ⇒ 5 spikes,
        // majority (5 ≥ 4) ⇒ activation 1.
        use MtjState::{AntiParallel as AP, Parallel as P};
        let (m, c) = setup();
        let reader = BurstReader::new(&m, &c);
        let res = reader.trace_pattern(&m, &[P, P, AP, AP, P, P, AP, P]);
        let spikes: Vec<bool> = res.steps.iter().map(|s| s.spike).collect();
        assert_eq!(
            spikes,
            vec![true, true, false, false, true, true, false, true]
        );
        assert!(res.activation);
        assert_eq!(res.steps.iter().filter(|s| s.spike).count(), 5);
    }

    #[test]
    fn minority_pattern_does_not_activate() {
        use MtjState::{AntiParallel as AP, Parallel as P};
        let (m, c) = setup();
        let reader = BurstReader::new(&m, &c);
        let res = reader.trace_pattern(&m, &[P, AP, AP, AP, P, AP, AP, P]);
        assert!(!res.activation, "3 of 8 must not fire");
    }

    #[test]
    fn reset_returns_all_to_ap_and_costs_time() {
        use MtjState::{AntiParallel as AP, Parallel as P};
        let (m, c) = setup();
        let reader = BurstReader::new(&m, &c);
        let res = reader.trace_pattern(&m, &[P, P, P, P, P, P, P, P]);
        assert!(res.reset_pulses >= 8, "every P device needs ≥1 reset pulse");
        assert!(res.duration_ns > 8.0 * m.cfg().read_pulse_ns);
        let _ = AP;
    }

    #[test]
    fn all_ap_pattern_costs_no_resets() {
        use MtjState::AntiParallel as AP;
        let (m, c) = setup();
        let reader = BurstReader::new(&m, &c);
        let res = reader.trace_pattern(&m, &[AP; 8]);
        assert_eq!(res.reset_pulses, 0);
        assert!(!res.activation);
        // Pure read time: 8 × 500 ps = 4 ns.
        assert!((res.duration_ns - 8.0 * 0.5).abs() < 1e-9);
    }

    #[test]
    fn burst_read_duration_matches_pulse_budget() {
        use MtjState::Parallel as P;
        let (m, c) = setup();
        let reader = BurstReader::new(&m, &c);
        let res = reader.trace_pattern(&m, &[P; 8]);
        let min = 8.0 * m.cfg().read_pulse_ns + 8.0 * m.cfg().reset_pulse_ns;
        assert!(res.duration_ns >= min - 1e-9);
    }
}
