//! Behavioural circuit simulation of the in-pixel compute path
//! (paper §2.2, GlobalFoundries 22 nm FDX).
//!
//! * [`pixel`] — weight-augmented 3T pixel: photodiode, source-degenerated
//!   weight transistors, shared-bitline MAC, Fig. 4(a) transfer curve
//! * [`subtractor`] — two-phase capacitive subtractor with the paper's
//!   tunable threshold-matching scheme (V_OFS = 0.5·VDD + V_SW − V_TH)
//! * [`readout`] — MUX + comparator burst-read path and reset pulse
//!   generation (Fig. 6)

pub mod pixel;
pub mod readout;
pub mod subtractor;

pub use pixel::{fitted_nonlinearity, norm_to_volt, pixel_mac, volt_to_norm};
pub use readout::{BurstReadResult, BurstReader, SensePath};
pub use subtractor::{threshold_to_volts, AnalogSubtractor, SubtractorOutput};
