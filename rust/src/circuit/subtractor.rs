//! Passive analog subtractor + tunable threshold matching (paper §2.2.2).
//!
//! Two-phase capacitive subtraction (Fig. 3c): during phase 1 both S1 and
//! S2 close, storing the negative-weight MAC on the top plate of C_H while
//! the bottom plate charges to the DC offset V_OFS; during phase 2 only S1
//! stays closed, so the top-plate swing (positive-weight MAC minus the
//! stored value) couples onto the floating bottom plate:
//!
//! `V_CONV = V_OFS + (V_M,pos − V_M,neg)`
//!
//! **Threshold matching** (the paper's §2.2.2 contribution): the VC-MTJ
//! switches at a device-determined `V_SW` which generally differs from the
//! algorithm's threshold.  Setting `V_OFS = 0.5·VDD + (V_SW − V_TH)` makes
//! "algorithm says fire" coincide with "V_CONV ≥ V_SW".  V_OFS is a global
//! external bias, so the algorithmic threshold stays tunable after
//! fabrication.

use crate::config::CircuitConfig;
use crate::circuit::pixel::norm_to_volt;

/// Buffered output rail: the unity-gain buffer runs from a boosted IO
/// supply (GF22FDX thick-oxide IO devices) so V_CONV can exceed the core
/// VDD and reach the MTJ write voltages.
pub const V_RAIL_MAX: f64 = 1.8;

/// The subtractor with its programmed offset.
#[derive(Debug, Clone)]
pub struct AnalogSubtractor {
    cfg: CircuitConfig,
    /// Programmed DC offset (V): `0.5·VDD + (V_SW − V_TH)`.
    v_ofs: f64,
}

/// Captured two-phase operation (for transient traces / Fig. 4b).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SubtractorOutput {
    /// Final convolution voltage on the bottom plate (V), rail-clamped.
    pub v_conv: f64,
    /// True if the output clipped at a rail (saturation is benign past
    /// threshold, per the paper, but we track it for diagnostics).
    pub saturated: bool,
}

impl AnalogSubtractor {
    /// `v_sw`: MTJ switching voltage; `v_th_alg_volts`: the hardware-mapped
    /// algorithmic threshold *as a differential voltage* (see
    /// [`threshold_to_volts`]).
    pub fn with_threshold_matching(
        cfg: &CircuitConfig,
        v_sw: f64,
        v_th_alg_volts: f64,
    ) -> Self {
        let v_ofs = 0.5 * cfg.vdd + (v_sw - v_th_alg_volts);
        Self { cfg: cfg.clone(), v_ofs }
    }

    /// Plain subtractor with mid-rail offset (no threshold matching) —
    /// the configuration a multi-bit-ADC readout would use.
    pub fn mid_rail(cfg: &CircuitConfig) -> Self {
        Self { cfg: cfg.clone(), v_ofs: 0.5 * cfg.vdd }
    }

    pub fn v_ofs(&self) -> f64 {
        self.v_ofs
    }

    /// Run both phases: `mac_neg`/`mac_pos` are the normalized
    /// post-nonlinearity MACs from the pixel array (phase 1 / phase 2).
    pub fn subtract(&self, mac_neg: f64, mac_pos: f64) -> SubtractorOutput {
        let v_neg = norm_to_volt(mac_neg, &self.cfg);
        let v_pos = norm_to_volt(mac_pos, &self.cfg);
        let ideal = self.v_ofs + (v_pos - v_neg);
        let v_conv = ideal.clamp(0.0, V_RAIL_MAX);
        SubtractorOutput { v_conv, saturated: (ideal - v_conv).abs() > 1e-12 }
    }

    /// RC settling time-constant of the sampling network (ns).
    pub fn tau_ns(&self) -> f64 {
        // R_on · C_H: Ω · fF = 1e-15 s·1e9 ns = 1e-6 ns per Ω·fF.
        self.cfg.switch_r_on_ohm * self.cfg.c_hold_ff * 1e-6
    }

    /// Transient trace of the two-phase operation (regenerates Fig. 4b).
    ///
    /// Returns `(t_ns, v_top, v_conv)` samples: phase 1 settles the top
    /// plate to V_M(neg) and the bottom to V_OFS; phase 2 re-settles the
    /// top to V_M(pos) with the bottom riding the coupled difference.
    pub fn transient(
        &self,
        mac_neg: f64,
        mac_pos: f64,
        phase_ns: f64,
        n_samples: usize,
    ) -> Vec<(f64, f64, f64)> {
        let tau = self.tau_ns().max(1e-3);
        let v_neg = norm_to_volt(mac_neg, &self.cfg);
        let v_pos = norm_to_volt(mac_pos, &self.cfg);
        let mut out = Vec::with_capacity(2 * n_samples);
        // Phase 1: top: 0 → v_neg; bottom pinned at v_ofs.
        for i in 0..n_samples {
            let t = phase_ns * i as f64 / n_samples as f64;
            let settle = 1.0 - (-t / tau).exp();
            out.push((t, v_neg * settle, self.v_ofs));
        }
        // Phase 2: top: v_neg → v_pos; bottom floats, coupled 1:1.
        for i in 0..n_samples {
            let t = phase_ns * i as f64 / n_samples as f64;
            let settle = 1.0 - (-t / tau).exp();
            let v_top = v_neg + (v_pos - v_neg) * settle;
            let v_conv = (self.v_ofs + (v_top - v_neg)).clamp(0.0, V_RAIL_MAX);
            out.push((phase_ns + t, v_top, v_conv));
        }
        out
    }
}

/// Convert a normalized algorithmic threshold (in post-nonlinearity MAC
/// units, e.g. `E(z_clip)·v_th − shift_c`) into the *absolute* hardware
/// threshold voltage V_TH of the paper's offset formula.  V_TH is
/// mid-rail-referenced (a MAC difference of exactly θ lands the bottom
/// plate at `V_OFS + θ_scaled`, and V_OFS cancels the mid-rail term), so
/// `V_TH = norm_to_volt(θ)` — with this convention
/// `V_CONV ≥ V_SW  ⟺  (mac_pos − mac_neg) ≥ θ`.
pub fn threshold_to_volts(theta_norm: f64, cfg: &CircuitConfig) -> f64 {
    norm_to_volt(theta_norm, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CircuitConfig;

    fn cfg() -> CircuitConfig {
        CircuitConfig::default()
    }

    #[test]
    fn offset_formula_matches_paper() {
        let c = cfg();
        let s = AnalogSubtractor::with_threshold_matching(&c, 0.8, 0.1);
        assert!((s.v_ofs() - (0.5 * c.vdd + 0.7)).abs() < 1e-12);
    }

    #[test]
    fn subtraction_is_difference_plus_offset() {
        let c = cfg();
        let s = AnalogSubtractor::mid_rail(&c);
        let out = s.subtract(0.5, 1.25);
        let want = 0.5 * c.vdd + (norm_to_volt(1.25, &c) - norm_to_volt(0.5, &c));
        assert!((out.v_conv - want).abs() < 1e-12);
        assert!(!out.saturated);
    }

    #[test]
    fn threshold_matching_fires_exactly_at_algorithmic_threshold() {
        // The core §2.2.2 property: V_CONV ≥ V_SW ⟺ (mac_pos − mac_neg)
        // ≥ θ, independent of the device's V_SW.
        let c = cfg();
        let theta_norm = 0.8; // algorithmic threshold in MAC units
        let v_th = threshold_to_volts(theta_norm, &c);
        for v_sw in [0.6, 0.8, 1.0] {
            let s = AnalogSubtractor::with_threshold_matching(&c, v_sw, v_th);
            for delta in [-1.2, -0.1, 0.0, 0.05, 0.79, 0.81, 1.5, 2.9] {
                let out = s.subtract(0.0, delta);
                let fires = out.v_conv >= v_sw - 1e-12;
                let should = delta >= theta_norm - 1e-12;
                assert_eq!(
                    fires, should,
                    "v_sw={v_sw} delta={delta}: v_conv={}",
                    out.v_conv
                );
            }
        }
    }

    #[test]
    fn saturation_does_not_break_firing_decision() {
        // Paper: "the skewed offset will not impact the final activation
        // … even if the analog convolution output saturates".
        let c = cfg();
        let s = AnalogSubtractor::with_threshold_matching(&c, 0.8, 0.05);
        let out = s.subtract(-2.9, 2.9); // enormous positive difference
        assert!(out.saturated);
        assert!(out.v_conv >= 0.8, "still above V_SW after clamping");
    }

    #[test]
    fn negative_rail_clamps_to_ground() {
        let c = cfg();
        let s = AnalogSubtractor::mid_rail(&c);
        let out = s.subtract(2.9, -2.9);
        assert_eq!(out.v_conv, 0.0);
        assert!(out.saturated);
    }

    #[test]
    fn transient_settles_to_final_values() {
        let c = cfg();
        let s = AnalogSubtractor::mid_rail(&c);
        let trace = s.transient(0.5, 1.25, 50.0, 100);
        let (_, v_top_end, v_conv_end) = *trace.last().unwrap();
        assert!((v_top_end - norm_to_volt(1.25, &c)).abs() < 1e-3);
        let want = s.subtract(0.5, 1.25).v_conv;
        assert!((v_conv_end - want).abs() < 1e-3);
    }

    #[test]
    fn transient_phase1_bottom_pinned_to_ofs() {
        let c = cfg();
        let s = AnalogSubtractor::with_threshold_matching(&c, 0.8, 0.1);
        let trace = s.transient(1.0, 2.0, 50.0, 50);
        for &(t, _, v_conv) in trace.iter().take(50) {
            assert!((v_conv - s.v_ofs()).abs() < 1e-12, "t={t}");
        }
    }

    #[test]
    fn tau_is_physical() {
        let c = cfg();
        let s = AnalogSubtractor::mid_rail(&c);
        // 2 kΩ · 20 fF = 40 ps
        assert!((s.tau_ns() - 0.04).abs() < 1e-12);
    }
}
