//! PJRT runtime: load and execute the AOT artifacts (`artifacts/*.hlo.txt`).
//!
//! The interchange format is HLO *text* — jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).  All executables are
//! compiled once at startup and cached; execution is synchronous on the
//! caller thread (the PJRT CPU client runs its own thread pool internally).
//!
//! Compiled only under the `pjrt` cargo feature; the serving pipeline
//! reaches it through `backend::PjrtBackend`.  With the default in-tree
//! `vendor/xla-stub` dependency this module compiles but every runtime
//! entry point reports that real xla bindings are required.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::config::ArtifactMeta;

/// A compiled HLO module ready to execute.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 input buffers of the given shapes.  Returns the
    /// flattened f32 outputs (the AOT functions return 1-tuples which are
    /// unwrapped here; multi-output tuples come back in order).
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals = inputs
            .iter()
            .map(|(data, shape)| {
                xla::Literal::vec1(data)
                    .reshape(shape)
                    .with_context(|| format!("reshaping input to {shape:?}"))
            })
            .collect::<Result<Vec<_>>>()?;
        self.run_literals(&literals)
    }

    /// Execute with explicit literals (for non-f32 inputs, e.g. u32 seeds).
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing artifact '{}'", self.name))?;
        let buf = result
            .first()
            .and_then(|device| device.first())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact '{}' returned no output buffers",
                    self.name
                )
            })?;
        let lit = buf.to_literal_sync().with_context(|| {
            format!("fetching result literal of artifact '{}'", self.name)
        })?;
        // Most AOT exports return 1-tuples, but some lowerings emit a bare
        // array — accept both instead of failing on `to_tuple`.
        match lit.to_tuple() {
            Ok(parts) => parts
                .into_iter()
                .map(|p| {
                    p.to_vec::<f32>().with_context(|| {
                        format!(
                            "converting artifact '{}' tuple output to f32",
                            self.name
                        )
                    })
                })
                .collect(),
            Err(_) => Ok(vec![lit.to_vec::<f32>().with_context(|| {
                format!(
                    "converting artifact '{}' non-tuple output to f32",
                    self.name
                )
            })?]),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// PJRT CPU runtime with an executable cache keyed by artifact stem.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    pub meta: Option<ArtifactMeta>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifacts directory.
    pub fn cpu<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let dir = artifacts_dir.as_ref().to_path_buf();
        let meta = ArtifactMeta::from_dir(&dir).ok();
        Ok(Self { client, artifacts_dir: dir, cache: Mutex::new(HashMap::new()), meta })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<stem>.hlo.txt` (cached).
    pub fn load(&self, stem: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(stem) {
            return Ok(e.clone());
        }
        let path = self.artifacts_dir.join(format!("{stem}.hlo.txt"));
        if !path.exists() {
            bail!("artifact {:?} missing — run `make artifacts`", path);
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {stem}"))?;
        let e = std::sync::Arc::new(Executable { name: stem.to_string(), exe });
        self.cache.lock().unwrap().insert(stem.to_string(), e.clone());
        Ok(e)
    }

    /// Preload the standard artifact set for the configured batch sizes.
    pub fn preload(&self, batches: &[usize]) -> Result<()> {
        for &b in batches {
            for stem in ["frontend", "frontend_mtj", "backend", "full"] {
                self.load(&format!("{stem}_b{b}"))?;
            }
        }
        Ok(())
    }
}

/// Helper: build a u32 scalar literal (e.g. the per-frame MTJ seed).
pub fn u32_scalar(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts().join("meta.json").exists()
    }

    #[test]
    fn missing_artifact_is_friendly_error() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::cpu(artifacts()).unwrap();
        let err = match rt.load("nonexistent_model") {
            Err(e) => e,
            Ok(_) => panic!("load of missing artifact must fail"),
        };
        assert!(format!("{err}").contains("make artifacts"));
    }

    #[test]
    fn loads_and_caches_backend() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::cpu(artifacts()).unwrap();
        let a = rt.load("backend_b1").unwrap();
        let b = rt.load("backend_b1").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b), "second load must hit cache");
    }

    #[test]
    fn backend_executes_with_correct_shapes() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::cpu(artifacts()).unwrap();
        let meta = rt.meta.as_ref().unwrap().clone();
        let exe = rt.load("backend_b1").unwrap();
        let n: usize = meta.act_shape.iter().product();
        let input = vec![0.0f32; n];
        let shape: Vec<i64> = meta.act_shape.iter().map(|&d| d as i64).collect();
        let out = exe.run_f32(&[(&input, &shape)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), meta.num_classes);
        assert!(out[0].iter().all(|x| x.is_finite()));
    }
}
