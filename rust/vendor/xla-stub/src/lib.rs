//! Offline stand-in for the `xla` (PJRT) bindings.
//!
//! The pixelmtj `pjrt` cargo feature needs an `xla` crate to compile, but
//! the build environment has no XLA toolchain.  This stub mirrors exactly
//! the API surface `pixelmtj::runtime` uses and fails at *runtime* with a
//! descriptive error instead of failing the *build*.  To execute AOT
//! artifacts for real, point the `xla` dependency in `rust/Cargo.toml`
//! (or a `[patch]` section in the workspace root) at real bindings, e.g.
//! a local checkout of xla-rs built against `xla_extension`.

use std::fmt;

/// Error type mirroring the real bindings' error behaviour closely enough
/// for `anyhow::Context` chaining.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: this build links the in-tree xla stub (no PJRT). \
         Replace rust/vendor/xla-stub with real xla bindings to execute \
         AOT artifacts, or run with the default native backend instead"
    )))
}

/// Host literal: carries data so `vec1`/`scalar`/`reshape` construction
/// succeeds; device-side conversions report the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    _f32s: Vec<f32>,
    _dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { _f32s: data.to_vec(), _dims: vec![data.len() as i64] }
    }

    pub fn scalar(v: u32) -> Literal {
        Literal { _f32s: vec![v as f32], _dims: Vec::new() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _f32s: self._f32s.clone(), _dims: dims.to_vec() })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// PJRT client handle; `cpu()` always fails in the stub, so the
/// execution methods below are unreachable but keep callers typechecked.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_succeeds_execution_reports_stub() {
        let lit = Literal::vec1(&[1.0, 2.0]).reshape(&[2]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err}").contains("stub"));
    }
}
