//! Golden cross-language validation: the AOT artifacts must reproduce the
//! Python oracle bit-for-bit, and the rust sensor simulator must agree
//! with both.  Skips gracefully when artifacts have not been built.

use std::path::PathBuf;

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts().join("meta.json").exists()
        && artifacts().join("golden.json").exists()
}

#[test]
fn all_validation_checks_pass() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let checks = pixelmtj::validate::run_checks(&artifacts()).unwrap();
    // 4 native checks always.  Under `pjrt` either +4 AOT executable
    // checks (real xla bindings) or +1 failing runtime-construction check
    // (the vendor stub) — the pass assertions below are the gate for the
    // latter, not the count.
    if cfg!(feature = "pjrt") {
        assert!(
            checks.len() == 8 || checks.len() == 5,
            "unexpected check count {}",
            checks.len()
        );
    } else {
        assert_eq!(checks.len(), 4);
    }
    for c in &checks {
        assert!(c.pass, "check '{}' failed: {}", c.name, c.detail);
    }
}

#[test]
fn validate_report_is_human_readable() {
    if !have_artifacts() {
        return;
    }
    let report = pixelmtj::validate::run(&artifacts()).unwrap();
    assert!(report.contains("VALID"));
    assert!(report.contains("rust sensor sim vs golden frontend"));
    assert!(report.contains("native packed vs dense reference"));
    if cfg!(feature = "pjrt") {
        assert!(report.contains("frontend_b1"));
    }
}

#[test]
fn hwcfg_json_matches_rust_defaults() {
    if !have_artifacts() {
        return;
    }
    // The single-source-of-truth contract between hwcfg.py and config/.
    let from_json = pixelmtj::config::HwConfig::from_json_file(
        artifacts().join("hwcfg.json"),
    )
    .unwrap();
    assert_eq!(from_json, pixelmtj::config::HwConfig::default());
}

#[test]
fn golden_frontend_sparsity_in_trained_band() {
    if !have_artifacts() {
        return;
    }
    // Trained BNN activations should be sparse (paper §3.2: ≥75 %).
    let v = pixelmtj::util::json::Value::from_file(
        &artifacts().join("golden.json"),
    )
    .unwrap();
    let bits = v.get("frontend_out").unwrap().as_f32_vec().unwrap();
    let sparsity = 1.0 - bits.iter().sum::<f32>() as f64 / bits.len() as f64;
    assert!(
        sparsity > 0.5,
        "trained frontend sparsity {sparsity} suspiciously low"
    );
}
