//! Property tests over coordinator + substrate invariants, driven by the
//! in-tree deterministic property harness (`util::prop` — the offline
//! registry has no proptest; failures reproduce from the printed case
//! number).

use std::time::{Duration, Instant};

use pixelmtj::circuit::subtractor::{threshold_to_volts, AnalogSubtractor};
use pixelmtj::config::{CircuitConfig, HwConfig, MtjConfig, SparseCoding};
use pixelmtj::coordinator::sparse::{decode, encode, Encoded};
use pixelmtj::coordinator::Batcher;
use pixelmtj::device::interp::MonotoneCubic;
use pixelmtj::device::mtj::{MtjModel, MtjState};
use pixelmtj::device::{faulty_neuron_error_rates, neuron_error_rates, StuckFaults};
use pixelmtj::sensor::{
    BitPlane, CaptureMode, FirstLayerWeights, Frame, OperatingPoint,
    PixelArraySim,
};
use pixelmtj::util::prop::{check, Gen};

fn arbitrary_map(g: &mut Gen) -> BitPlane {
    let c = g.usize_in(1, 8);
    let h = g.usize_in(1, 20);
    let w = g.usize_in(1, 20);
    let p = g.f64_in(0.0, 1.0);
    let bools = g.vec_bool(c * h * w, p);
    BitPlane::from_bools(c, h, w, &bools, g.u32()).unwrap()
}

#[test]
fn prop_bitplane_pack_roundtrip_and_counts() {
    // The packed representation is lossless vs the bool one, and every
    // word-level aggregate (count_ones, sparsity, flips) matches a
    // per-element reference computed from the bools.
    check("bitplane pack roundtrip", 200, |g| {
        let c = g.usize_in(1, 8);
        let h = g.usize_in(1, 20);
        let w = g.usize_in(1, 20);
        let p_one = g.f64_in(0.0, 1.0);
        let bools = g.vec_bool(c * h * w, p_one);
        let m = BitPlane::from_bools(c, h, w, &bools, g.u32())
            .map_err(|e| e.to_string())?;
        if m.to_bools() != bools {
            return Err("to_bools != source bools".into());
        }
        let ones = bools.iter().filter(|&&b| b).count() as u64;
        if m.count_ones() != ones {
            return Err(format!("count_ones {} != {ones}", m.count_ones()));
        }
        let want_sparsity = 1.0 - ones as f64 / bools.len() as f64;
        if (m.sparsity() - want_sparsity).abs() > 1e-12 {
            return Err("sparsity mismatch".into());
        }
        // Directional flips vs a second random plane, word-level XOR
        // against the element-level reference.
        let p_other = g.f64_in(0.0, 1.0);
        let other_bools = g.vec_bool(c * h * w, p_other);
        let other =
            BitPlane::from_bools(c, h, w, &other_bools, 0).unwrap();
        let (mut r10, mut r01) = (0u64, 0u64);
        for (&a, &b) in bools.iter().zip(other_bools.iter()) {
            r10 += u64::from(a && !b);
            r01 += u64::from(!a && b);
        }
        if m.flips(&other) != (r10, r01) {
            return Err("flips mismatch vs element reference".into());
        }
        Ok(())
    });
}

#[test]
fn prop_codec_roundtrip_all_codings() {
    check("codec roundtrip", 150, |g| {
        let m = arbitrary_map(g);
        for coding in [SparseCoding::Dense, SparseCoding::Csr, SparseCoding::Rle] {
            let enc = encode(&m, coding);
            let dec = decode(&enc).map_err(|e| format!("{coding:?}: {e}"))?;
            if dec != m {
                return Err(format!("{coding:?} roundtrip mismatch"));
            }
            if enc.payload_bits == 0 && !m.is_empty() {
                return Err("zero payload for nonempty map".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hostile_wire_bytes_never_panic() {
    // The codec-hardening contract: any truncation or byte-level
    // mutation of a valid wire body must come back as `Ok` or `Err` from
    // parse + decode — never a panic — across all three codings.  This
    // is what keeps a hostile `FRAME` body from killing a stage thread.
    check("hostile wire bytes", 150, |g| {
        let m = arbitrary_map(g);
        let (c, h, w) = (m.channels, m.height, m.width);
        for coding in [SparseCoding::Dense, SparseCoding::Csr, SparseCoding::Rle] {
            let bytes = encode(&m, coding).wire_bytes();
            let seq = m.seq;
            let run = |body: &[u8]| {
                Encoded::from_wire_bytes(coding, c, h, w, seq, body).and_then(|e| decode(&e))
            };
            // The untouched body must still round-trip.
            let intact = run(&bytes).map_err(|e| format!("{coding:?}: intact body: {e}"))?;
            if intact != m {
                return Err(format!("{coding:?}: intact body mismatch"));
            }
            // Truncations: fixed fractions plus a random cut point.
            let n = bytes.len();
            for cut in [0, n / 4, n / 2, 3 * n / 4, g.usize_in(0, n)] {
                let _ = run(&bytes[..cut]);
            }
            // Byte mutations: 1–4 random nonzero XORs per round.
            for _ in 0..4 {
                let mut mutated = bytes.clone();
                for _ in 0..g.usize_in(1, 4) {
                    let i = g.usize_in(0, mutated.len() - 1);
                    mutated[i] ^= (g.u32() % 255 + 1) as u8;
                }
                let _ = run(&mutated);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hostile_batch_envelopes_never_panic() {
    use pixelmtj::config::WireCoding;
    use pixelmtj::wire::{proto, Msg};
    // The v2 extension of the codec-hardening contract: any truncation
    // or byte-level mutation of a valid FRAME_BATCH / RESULT_BATCH
    // envelope must come back as `Ok` or `Err` from the shared decoder —
    // never a panic — so a hostile batch cannot kill the reactor thread.
    check("hostile batch envelopes", 150, |g| {
        let count = g.usize_in(1, 6);
        let bodies: Vec<Vec<u8>> = (0..count)
            .map(|_| {
                let n = g.usize_in(0, 32);
                (0..n).map(|_| (g.u32() & 0xff) as u8).collect()
            })
            .collect();
        let coding = match g.u32() % 4 {
            0 => WireCoding::F32,
            1 => WireCoding::Dense,
            2 => WireCoding::Csr,
            _ => WireCoding::Rle,
        };
        let frames =
            Msg::FrameBatch { first_seq: g.u32(), coding, bodies }.encode();
        let results = Msg::ResultBatch {
            results: (0..count)
                .map(|i| {
                    (
                        g.u32(),
                        (u64::from(g.u32()) << 32) | i as u64,
                        (g.u32() & 0xffff) as u16,
                    )
                })
                .collect(),
        }
        .encode();
        for bytes in [frames, results] {
            // The intact envelope round-trips canonically.
            let (msg, used) = proto::decode(&bytes)
                .map_err(|e| format!("intact envelope: {e}"))?;
            if used != bytes.len() {
                return Err("intact decode left trailing bytes".into());
            }
            if msg.encode() != bytes {
                return Err("re-encode diverged from the original".into());
            }
            // Truncations at fixed fractions plus a random cut point.
            let n = bytes.len();
            for cut in [0, n / 4, n / 2, 3 * n / 4, g.usize_in(0, n)] {
                let _ = proto::decode(&bytes[..cut]);
            }
            // Byte mutations: 1–4 random nonzero XORs per round, hitting
            // the magic, type byte, envelope length, counts, and the
            // per-body length table alike.
            for _ in 0..4 {
                let mut mutated = bytes.clone();
                for _ in 0..g.usize_in(1, 4) {
                    let i = g.usize_in(0, mutated.len() - 1);
                    mutated[i] ^= (g.u32() % 255 + 1) as u8;
                }
                let _ = proto::decode(&mutated);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dense_payload_is_exactly_one_bit_per_element() {
    check("dense payload", 50, |g| {
        let m = arbitrary_map(g);
        let enc = encode(&m, SparseCoding::Dense);
        if enc.payload_bits != m.len() as u64 {
            return Err(format!("{} != {}", enc.payload_bits, m.len()));
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_emits_only_configured_sizes_and_preserves_fifo() {
    check("batcher sizes+fifo", 100, |g| {
        let sizes = vec![1usize, g.usize_in(2, 16)];
        let timeout = Duration::from_micros(g.usize_in(0, 500) as u64);
        let mut b = Batcher::new(sizes.clone(), timeout);
        let n = g.usize_in(0, 100);
        for i in 0..n {
            b.push(i);
        }
        let mut drained = Vec::new();
        let deadline = Instant::now() + Duration::from_millis(10);
        while let Some(batch) = b.poll(deadline, true) {
            if !sizes.contains(&batch.len()) {
                return Err(format!("illegal batch size {}", batch.len()));
            }
            drained.extend(batch);
        }
        if drained != (0..n).collect::<Vec<_>>() {
            return Err("FIFO violated".into());
        }
        if !b.is_empty() {
            return Err("flush left items behind".into());
        }
        Ok(())
    });
}

#[test]
fn prop_threshold_matching_equivalence() {
    // ∀ (v_sw, θ, Δ): V_CONV ≥ V_SW ⟺ Δ ≥ θ — the paper's §2.2.2
    // tunable-mapping contract, for any device switching voltage.
    let cfg = CircuitConfig::default();
    check("threshold matching", 300, |g| {
        let v_sw = g.f64_in(0.5, 1.1);
        let theta = g.f64_in(-1.5, 1.5);
        let delta = g.f64_in(-2.9, 2.9);
        let sub = AnalogSubtractor::with_threshold_matching(
            &cfg,
            v_sw,
            threshold_to_volts(theta, &cfg),
        );
        let out = sub.subtract(0.0, delta);
        let fires = out.v_conv >= v_sw - 1e-9;
        let should = delta >= theta - 1e-9;
        if fires != should && (delta - theta).abs() > 1e-6 {
            return Err(format!(
                "v_sw={v_sw} θ={theta} Δ={delta}: fires={fires} should={should}"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_switching_probability_monotone_in_voltage() {
    let model = MtjModel::new(&MtjConfig::default());
    check("P_sw monotone", 200, |g| {
        let v1 = g.f64_in(0.0, 1.2);
        let v2 = g.f64_in(0.0, 1.2);
        let (lo, hi) = if v1 < v2 { (v1, v2) } else { (v2, v1) };
        let p_lo = model.switching_probability(MtjState::AntiParallel, lo, 0.7);
        let p_hi = model.switching_probability(MtjState::AntiParallel, hi, 0.7);
        if p_lo > p_hi + 1e-9 {
            return Err(format!("P({lo})={p_lo} > P({hi})={p_hi}"));
        }
        Ok(())
    });
}

#[test]
fn prop_monotone_cubic_never_overshoots() {
    check("pchip bounds", 100, |g| {
        let n = g.usize_in(2, 8);
        let mut xs = vec![0.0];
        for _ in 1..n {
            xs.push(xs.last().unwrap() + g.f64_in(0.05, 1.0));
        }
        let mut ys = vec![g.f64_in(0.0, 0.1)];
        for _ in 1..n {
            ys.push(ys.last().unwrap() + g.f64_in(0.0, 0.5));
        }
        let c = MonotoneCubic::new(xs.clone(), ys.clone());
        let (lo, hi) = (ys[0], *ys.last().unwrap());
        let mut prev = f64::NEG_INFINITY;
        for i in 0..=200 {
            let x = xs[0] + (xs[n - 1] - xs[0]) * i as f64 / 200.0;
            let y = c.eval(x);
            if y < lo - 1e-9 || y > hi + 1e-9 {
                return Err(format!("overshoot at {x}: {y} ∉ [{lo}, {hi}]"));
            }
            if y < prev - 1e-9 {
                return Err(format!("non-monotone at {x}"));
            }
            prev = y;
        }
        Ok(())
    });
}

#[test]
fn prop_majority_error_decreases_with_devices() {
    check("majority monotone", 100, |g| {
        let p_fire = g.f64_in(0.6, 0.99);
        let (e1, _) = neuron_error_rates(p_fire, 0.0, 1, 1);
        let (e8, _) = neuron_error_rates(p_fire, 0.0, 8, 4);
        if e8 > e1 + 1e-12 {
            return Err(format!("8-device error {e8} > single {e1}"));
        }
        Ok(())
    });
}

/// Random `(p_fire, p_err, n, k)` with `k ≤ n` — the healthy-neuron part
/// of a fault-model case.
fn arbitrary_neuron(g: &mut Gen) -> (f64, f64, usize, usize) {
    let n = g.usize_in(1, 12);
    let k = g.usize_in(1, n);
    (g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0), n, k)
}

#[test]
fn prop_faulty_rates_reduce_to_healthy_at_zero_faults() {
    check("fault model reduction", 250, |g| {
        let (p_fire, p_err, n, k) = arbitrary_neuron(g);
        let (a10, a01) = faulty_neuron_error_rates(
            p_fire,
            p_err,
            n,
            k,
            StuckFaults::default(),
        );
        let (b10, b01) = neuron_error_rates(p_fire, p_err, n, k);
        if (a10 - b10).abs() > 1e-12 || (a01 - b01).abs() > 1e-12 {
            return Err(format!(
                "zero-fault mismatch at (p_fire={p_fire}, p_err={p_err}, \
                 n={n}, k={k}): ({a10}, {a01}) vs ({b10}, {b01})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_faulty_rates_monotone_in_stuck_faults() {
    // One more dead device can only raise the fail-to-fire rate; one
    // more stuck-P device can only raise the spurious-fire rate.
    check("fault model monotone", 250, |g| {
        let (p_fire, p_err, n, k) = arbitrary_neuron(g);
        let ap = g.usize_in(0, n.saturating_sub(1));
        let p = g.usize_in(0, n - 1 - ap.min(n - 1));
        if ap + p >= n {
            return Ok(()); // no headroom to add a fault
        }
        let base = StuckFaults::new(ap, p);
        let (e10, e01) = faulty_neuron_error_rates(p_fire, p_err, n, k, base);
        let (e10_dead, _) = faulty_neuron_error_rates(
            p_fire,
            p_err,
            n,
            k,
            StuckFaults::new(ap + 1, p),
        );
        if e10_dead < e10 - 1e-12 {
            return Err(format!(
                "stuck-AP {ap}→{} lowered e10 {e10}→{e10_dead} \
                 (p_fire={p_fire}, n={n}, k={k}, p={p})",
                ap + 1
            ));
        }
        let (_, e01_stuck) = faulty_neuron_error_rates(
            p_fire,
            p_err,
            n,
            k,
            StuckFaults::new(ap, p + 1),
        );
        if e01_stuck < e01 - 1e-12 {
            return Err(format!(
                "stuck-P {p}→{} lowered e01 {e01}→{e01_stuck} \
                 (p_err={p_err}, n={n}, k={k}, ap={ap})",
                p + 1
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_faulty_rates_stay_probabilities() {
    check("fault model bounds", 400, |g| {
        let (p_fire, p_err, n, k) = arbitrary_neuron(g);
        let ap = g.usize_in(0, n);
        let p = g.usize_in(0, n - ap);
        let (e10, e01) = faulty_neuron_error_rates(
            p_fire,
            p_err,
            n,
            k,
            StuckFaults::new(ap, p),
        );
        for (name, e) in [("e10", e10), ("e01", e01)] {
            if !(0.0..=1.0).contains(&e) || !e.is_finite() {
                return Err(format!(
                    "{name}={e} outside [0,1] at (p_fire={p_fire}, \
                     p_err={p_err}, n={n}, k={k}, ap={ap}, p={p})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_capture_deterministic_and_stats_consistent() {
    let hw = HwConfig::default();
    let sim = PixelArraySim::new(
        hw,
        FirstLayerWeights::synthetic(8, 3, 3, 2),
    );
    check("capture determinism", 25, |g| {
        let h = g.usize_in(8, 24);
        let w = g.usize_in(8, 24);
        let mut frame = Frame::new(3, h, w, g.u32());
        let data = g.vec_f64(3 * h * w, 0.0, 1.0);
        for (d, s) in frame.data.iter_mut().zip(data.iter()) {
            *d = *s as f32;
        }
        let (a, sa) = sim.capture(&frame, CaptureMode::CalibratedMtj);
        let (b, sb) = sim.capture(&frame, CaptureMode::CalibratedMtj);
        if a != b || sa != sb {
            return Err("capture not deterministic".into());
        }
        if sa.ones != a.count_ones() {
            return Err("stats.ones inconsistent".into());
        }
        if sa.elements as usize != a.len() {
            return Err("stats.elements inconsistent".into());
        }
        Ok(())
    });
}

#[test]
fn prop_packed_capture_equals_bool_reference() {
    // THE representation-equivalence property (the refactor's contract):
    // packed capture is bit-identical to the pre-refactor bool path in
    // every capture mode, at arbitrary operating points including
    // nonzero stuck-at faults and P_sw variability.
    let sim = PixelArraySim::new(
        HwConfig::default(),
        FirstLayerWeights::synthetic(8, 3, 3, 2),
    );
    check("packed capture = bool reference", 10, |g| {
        let h = g.usize_in(8, 18);
        let w = g.usize_in(8, 18);
        let mut frame = Frame::new(3, h, w, g.u32());
        let data = g.vec_f64(3 * h * w, 0.0, 1.0);
        for (d, s) in frame.data.iter_mut().zip(data.iter()) {
            *d = *s as f32;
        }
        let n = g.usize_in(1, 8);
        let k = g.usize_in(1, n);
        let ap = g.usize_in(0, n);
        let p = g.usize_in(0, n - ap);
        let op = OperatingPoint {
            v_write: g.f64_in(0.65, 0.95),
            pulse_ns: 0.7,
            n,
            k,
            faults: StuckFaults::new(ap, p),
            sigma_psw: g.f64_in(0.0, 0.3),
            sigma_seed: g.u32(),
        };
        for mode in [
            CaptureMode::Ideal,
            CaptureMode::CalibratedMtj,
            CaptureMode::PhysicalMtj,
        ] {
            let (plane, sa) = sim.capture_at(&frame, &op, mode);
            let (bits, sb) = sim.capture_at_ref(&frame, &op, mode);
            if plane.to_bools() != bits {
                return Err(format!("{mode:?}: packed bits != bool bits"));
            }
            if sa != sb {
                return Err(format!("{mode:?}: stats diverged"));
            }
            let (dplane, da) = sim.capture(&frame, mode);
            let (dbits, db) = sim.capture_ref(&frame, mode);
            if dplane.to_bools() != dbits || da != db {
                return Err(format!("{mode:?}: default capture diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_latency_histogram_empty_is_zero_not_nan() {
    use pixelmtj::metrics::LatencyHistogram;
    // An empty histogram reports 0 for the mean and for every quantile —
    // including out-of-range q — never NaN, never a panic.
    check("empty histogram", 60, |g| {
        let h = LatencyHistogram::default();
        let q = g.f64_in(-3.0, 3.0);
        if h.mean_us() != 0.0 {
            return Err(format!("empty mean {}", h.mean_us()));
        }
        if h.quantile_us(q) != 0 {
            return Err(format!("empty quantile({q}) != 0"));
        }
        if h.snapshot().count() != 0 {
            return Err("empty snapshot count != 0".into());
        }
        Ok(())
    });
}

#[test]
fn prop_latency_histogram_quantiles_monotone_in_q() {
    use pixelmtj::metrics::LatencyHistogram;
    check("histogram quantile monotonicity", 120, |g| {
        let h = LatencyHistogram::default();
        let n = g.usize_in(1, 200);
        for _ in 0..n {
            h.record_us(g.usize_in(0, 5_000_000) as u64);
        }
        let mut q1 = g.f64_in(0.0, 1.0);
        let mut q2 = g.f64_in(0.0, 1.0);
        if q1 > q2 {
            std::mem::swap(&mut q1, &mut q2);
        }
        let (v1, v2) = (h.quantile_us(q1), h.quantile_us(q2));
        if v1 > v2 {
            return Err(format!("q{q1}={v1} > q{q2}={v2}"));
        }
        // Out-of-range q clamps to the endpoints.
        if h.quantile_us(-1.0) != h.quantile_us(0.0) {
            return Err("q<0 must clamp to q=0".into());
        }
        if h.quantile_us(2.0) != h.quantile_us(1.0) {
            return Err("q>1 must clamp to q=1".into());
        }
        Ok(())
    });
}

#[test]
fn prop_latency_histogram_overflow_lands_in_last_bucket() {
    use pixelmtj::metrics::LatencyHistogram;
    // Values beyond the top power-of-two bound (~17 s) land in the +Inf
    // tail bucket, stay counted, and cap the quantile walk.
    check("histogram overflow bucket", 60, |g| {
        let h = LatencyHistogram::default();
        let huge = (1u64 << 40) + g.u32() as u64;
        h.record_us(huge);
        let snap = h.snapshot();
        let &(le, cnt) = snap.buckets.last().unwrap();
        if !le.is_infinite() || cnt != 1 {
            return Err(format!("tail bucket ({le}, {cnt})"));
        }
        if snap.count() != 1 || h.count() != 1 {
            return Err("overflow observation lost".into());
        }
        if h.quantile_us(1.0) != 1u64 << 25 {
            return Err(format!("overflow p100 {}", h.quantile_us(1.0)));
        }
        if h.mean_us() != huge as f64 {
            return Err("overflow mean must use the exact sum".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_arbitrary_numeric_trees() {
    use pixelmtj::util::json::Value;
    check("json roundtrip", 100, |g| {
        let n = g.usize_in(0, 40);
        let xs = g.vec_f64(n, -1e6, 1e6);
        let v = Value::obj(vec![
            ("xs", Value::arr_f64(&xs)),
            ("flag", Value::Bool(g.bool())),
            ("name", Value::Str(format!("case-{}", g.u32()))),
        ]);
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            let back = Value::parse(&text).map_err(|e| e.to_string())?;
            if back != v {
                return Err("roundtrip mismatch".into());
            }
        }
        Ok(())
    });
}
