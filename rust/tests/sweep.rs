//! Sweep engine determinism regression.
//!
//! The load-bearing property of `pixelmtj::sweep` is that campaign
//! output is **bit-identical for any worker count**: every stochastic
//! draw derives from counter-RNG coordinates, cells reassemble by index,
//! and the report JSON excludes run facts (threads, wall-clock).
//!
//! Two layers of pinning:
//! 1. an in-process `--threads 1` vs `--threads 8` comparison (always
//!    runs — scheduling must not leak into results);
//! 2. a committed golden JSON at the paper's calibrated points
//!    (0.7/0.8/0.9 V @ 700 ps, n=8, k=5) guarding against cross-version
//!    drift.  If the golden is absent the test *blesses* it (writes the
//!    current output) so a toolchain-equipped checkout materializes it;
//!    CI auto-commits the blessed file on the next push to `main` and
//!    uploads it as the `sweep_golden` artifact.  To regenerate after an
//!    intentional model change: delete `tests/data/sweep_golden.json`
//!    and re-run `cargo test --test sweep`.

use std::path::PathBuf;

use pixelmtj::config::SweepConfig;
use pixelmtj::reports::sweep_report;
use pixelmtj::sweep::{run_sweep, run_sweep_with};
use pixelmtj::util::json::Value;

/// The golden campaign: the paper's three calibrated voltages at 700 ps
/// with the stricter n=8 / k=5 majority.  Small on purpose — the golden
/// file stays reviewable and the test fast.
fn golden_cfg(threads: usize) -> SweepConfig {
    SweepConfig {
        grid: "v=0.7,0.8,0.9;pulse=0.7;n=8;k=5".to_string(),
        trials: 6,
        threads,
        seed: 42,
        sensor_height: 24,
        sensor_width: 24,
        ..SweepConfig::default()
    }
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/sweep_golden.json")
}

#[test]
fn sweep_output_bit_identical_across_thread_counts() {
    let a = run_sweep(&golden_cfg(1)).unwrap();
    let b = run_sweep(&golden_cfg(8)).unwrap();
    assert_eq!(a.cells.len(), 3);
    let (ja, jb) = (sweep_report::to_json(&a), sweep_report::to_json(&b));
    assert_eq!(ja, jb, "sweep results differ between 1 and 8 threads");
    assert_eq!(
        ja.to_string_pretty(),
        jb.to_string_pretty(),
        "serialized sweep reports differ between 1 and 8 threads"
    );
}

#[test]
fn sweep_matches_committed_golden() {
    let got = sweep_report::to_json(&run_sweep(&golden_cfg(3)).unwrap());
    let path = golden_path();
    if !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got.to_string_pretty()).unwrap();
        // Verify the blessed file round-trips to the same value tree, so
        // serialization problems surface at bless time, not next run.
        assert_eq!(Value::from_file(&path).unwrap(), got);
        eprintln!(
            "blessed new sweep golden at {} — commit this file",
            path.display()
        );
        return;
    }
    let want = Value::from_file(&path).unwrap();
    assert_eq!(
        got,
        want,
        "sweep output drifted from the committed golden \
         ({}); if the device/capture model changed intentionally, delete \
         the file and re-run to re-bless",
        path.display()
    );
}

#[test]
fn streamed_sink_matches_collected_summary_and_json() {
    // The streamed report sink is progress plumbing only: every cell is
    // delivered exactly once, each streamed result equals its slot in
    // the collected grid-order summary, and the JSON payload is
    // unchanged vs a sink-less run (the golden-test contract).
    let mut streamed = Vec::new();
    let with_sink = run_sweep_with(&golden_cfg(4), |idx, cell| {
        streamed.push((idx, cell.clone()));
    })
    .unwrap();
    let without_sink = run_sweep(&golden_cfg(2)).unwrap();
    assert_eq!(streamed.len(), with_sink.cells.len());
    let mut seen = vec![0u32; with_sink.cells.len()];
    for (idx, cell) in &streamed {
        assert_eq!(cell, &with_sink.cells[*idx], "cell {idx}");
        seen[*idx] += 1;
    }
    assert!(seen.iter().all(|&n| n == 1), "delivery counts {seen:?}");
    assert_eq!(
        sweep_report::to_json(&with_sink).to_string_pretty(),
        sweep_report::to_json(&without_sink).to_string_pretty(),
        "sink must not perturb the deterministic JSON payload"
    );
}

#[test]
fn golden_campaign_reproduces_fig5_margins() {
    // Physics sanity on the golden campaign itself: at 0.7 V the k=5
    // majority never reaches threshold (driven devices fire at 6.2 %),
    // at 0.8/0.9 V the neuron recovers the ideal bits almost everywhere.
    let s = run_sweep(&golden_cfg(2)).unwrap();
    let e10: Vec<f64> = s.cells.iter().map(|c| c.e10).collect();
    assert!(e10[0] > 0.99, "0.7 V must fail to fire: e10 {e10:?}");
    assert!(e10[1] < 0.02, "0.8 V e10 {e10:?}");
    assert!(e10[2] < 0.01, "0.9 V e10 {e10:?}");
    // And agreement with the ideal classification path follows the same
    // ordering (0.7 V breaks the head; 0.8/0.9 V preserve it).
    assert!(s.cells[1].agreement >= s.cells[0].agreement);
}
