//! Telemetry integration tests: the embedded Prometheus exposition
//! server over a live pipeline (`/metrics`, `/healthz`, `/readyz`
//! through the full stream lifecycle), the per-frame trace-span JSONL
//! sink, and the readiness probe flipping to 503 naming the failed
//! stage after an induced backend death.  All on the native backend so
//! nothing skips.

use anyhow::{bail, Result};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use pixelmtj::backend::{InferenceBackend, NativeBackend};
use pixelmtj::config::{HwConfig, PipelineConfig};
use pixelmtj::coordinator::Pipeline;
use pixelmtj::metrics::http::{MetricsServer, Readiness};
use pixelmtj::metrics::registry::{register_up, Registry};
use pixelmtj::sensor::{
    scene::SceneGen, BitPlane, FirstLayerWeights, Frame, PixelArraySim,
};
use pixelmtj::system::System;
use pixelmtj::util::json::Value;

/// Minimal blocking HTTP GET against the exposition server; returns
/// `(status code, body)`.
fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect to metrics server");
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut raw = String::new();
    s.read_to_string(&mut raw).unwrap();
    let code = raw
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("malformed response: {raw:?}"));
    let body = raw.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    (code, body)
}

#[test]
fn metrics_endpoints_track_the_full_stream_lifecycle() {
    let trace_path =
        std::env::temp_dir().join("pixelmtj_telemetry_trace.jsonl");
    let _ = std::fs::remove_file(&trace_path);

    let mut sys = System::builder()
        .artifacts_dir("/nonexistent")
        .workers(2)
        .metrics_addr("127.0.0.1:0")
        .trace_log(trace_path.to_str().unwrap())
        .build();
    let mut server = sys.serve_telemetry().unwrap().expect("addr was set");
    let addr = server.local_addr();

    // Liveness is unconditional; readiness requires a running stream.
    let (code, body) = http_get(addr, "/healthz");
    assert_eq!((code, body.as_str()), (200, "ok\n"));
    let (code, body) = http_get(addr, "/readyz");
    assert_eq!(code, 503, "no stream started yet");
    assert!(body.contains("stream not started"), "{body:?}");

    let stream = sys.stream().unwrap();
    let (code, body) = http_get(addr, "/readyz");
    assert_eq!((code, body.as_str()), (200, "ready\n"));

    let gen = SceneGen::new(3, 32, 32);
    for i in 0..12u32 {
        stream.submit(gen.textured(i)).unwrap();
    }
    let results = stream.drain().unwrap();
    assert_eq!(results.len(), 12);

    let (code, text) = http_get(addr, "/metrics");
    assert_eq!(code, 200);
    for needle in [
        "pixelmtj_up 1",
        "pixelmtj_frames_in_total",
        "pixelmtj_frames_out_total",
        "pixelmtj_batches_total",
        "pixelmtj_link_bits_total",
        "pixelmtj_frame_queue_peak",
        "pixelmtj_stage_latency_us_bucket",
        "pixelmtj_stage_latency_us_count",
        "stage=\"capture\"",
        "stage=\"encode\"",
        "stage=\"infer\"",
        "# TYPE pixelmtj_stage_latency_us histogram",
    ] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
    assert!(
        text.contains(
            "pixelmtj_frames_out_total{backend=\"native\",coding=\"csr\"} 12"
        ),
        "{text}"
    );

    let (code, _) = http_get(addr, "/nope");
    assert_eq!(code, 404);

    stream.shutdown().unwrap();
    let (code, body) = http_get(addr, "/readyz");
    assert_eq!(code, 503, "stopped stream is not ready");
    assert!(body.contains("stream stopped"), "{body:?}");
    server.shutdown();

    // The trace sink got exactly one JSONL span per served frame, each
    // carrying the full schema.
    let trace = std::fs::read_to_string(&trace_path).unwrap();
    let lines: Vec<&str> = trace.lines().collect();
    assert_eq!(lines.len(), 12, "one span per frame");
    let mut seen_ids = std::collections::BTreeSet::new();
    for line in &lines {
        let v = Value::parse(line).unwrap();
        let id = v.get("trace_id").unwrap().as_str().unwrap().to_string();
        assert_eq!(id.len(), 16, "zero-padded hex trace id: {id:?}");
        seen_ids.insert(id);
        assert_eq!(v.get("coding").unwrap().as_str().unwrap(), "csr");
        for key in [
            "seq",
            "queue_wait_us",
            "capture_us",
            "encode_us",
            "batch_wait_us",
            "infer_us",
            "e2e_us",
            "batch_size",
            "payload_bits",
        ] {
            assert!(v.get(key).unwrap().as_f64().is_ok(), "{key} in {line}");
        }
        assert!(v.get("payload_bits").unwrap().as_f64().unwrap() > 0.0);
    }
    assert_eq!(seen_ids.len(), 12, "trace ids are distinct");
    let _ = std::fs::remove_file(&trace_path);
}

/// A backend whose inference path always errors: the frontend (capture
/// shapes, preload) delegates to the real native engine so the stream
/// starts cleanly, then the first dispatched batch kills the dispatcher.
struct FailingBackend(NativeBackend);

impl InferenceBackend for FailingBackend {
    fn name(&self) -> &'static str {
        "failing"
    }
    fn act_shape(&self) -> [usize; 3] {
        self.0.act_shape()
    }
    fn num_classes(&self) -> usize {
        self.0.num_classes()
    }
    fn preload(&self, batches: &[usize]) -> Result<()> {
        self.0.preload(batches)
    }
    fn run_frontend(&self, frame: &Frame) -> Result<BitPlane> {
        self.0.run_frontend(frame)
    }
    fn run_backend(&self, _acts: &[f32], _batch: usize) -> Result<Vec<f32>> {
        bail!("injected backend failure")
    }
    fn run_backend_packed(
        &self,
        _words: &[u64],
        _batch: usize,
    ) -> Result<Vec<f32>> {
        bail!("injected backend failure")
    }
}

#[test]
fn readyz_flips_to_503_naming_the_dead_stage() {
    let cfg = PipelineConfig {
        sensor_workers: 1,
        ..PipelineConfig::default()
    };
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 1);
    let sim = PixelArraySim::new(hw.clone(), weights.clone());
    let native = NativeBackend::new(hw, weights, 32, 32, 1);
    let pipeline =
        Pipeline::new(cfg, sim, Arc::new(FailingBackend(native))).unwrap();

    let reg = Arc::new(Registry::new());
    register_up(&reg).unwrap();
    pipeline
        .metrics()
        .register_into(&reg, &[("backend", "failing"), ("coding", "csr")])
        .unwrap();
    let health = pipeline.health();
    let ready: Readiness = Arc::new(move || health.ready());
    let mut server = MetricsServer::start("127.0.0.1:0", reg, ready).unwrap();
    let addr = server.local_addr();

    let stream = pipeline.stream().unwrap();
    let (code, _) = http_get(addr, "/readyz");
    assert_eq!(code, 200, "stages alive before the first batch");

    // Keep feeding until the dispatcher hits the poisoned backend and
    // records its death; readiness must flip to 503 naming the stage.
    let gen = SceneGen::new(3, 32, 32);
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut i = 0u32;
    loop {
        let _ = stream.try_submit(gen.textured(i));
        i += 1;
        let (code, body) = http_get(addr, "/readyz");
        if code == 503 {
            assert!(body.contains("stage failed: dispatcher"), "{body:?}");
            assert!(body.contains("injected backend failure"), "{body:?}");
            break;
        }
        assert!(
            Instant::now() < deadline,
            "dispatcher death never reached /readyz"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    let err = stream
        .shutdown()
        .expect_err("shutdown must surface the stage error");
    assert!(
        format!("{err:#}").contains("injected backend failure"),
        "{err:#}"
    );
    // The recorded failure is sticky: it outranks the stopped state.
    let (code, body) = http_get(addr, "/readyz");
    assert_eq!(code, 503);
    assert!(body.contains("dispatcher"), "{body:?}");
    server.shutdown();
}
