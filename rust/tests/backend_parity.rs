//! Parity suite for the native XNOR backend: the bit-packed popcount
//! path must be bit-identical to its dense f32 reference and consistent
//! with the sensor simulator's comparator output, across several seeds
//! and sensor shapes — plus a full end-to-end pipeline run on the native
//! backend with no artifacts and no skips.

use std::sync::Arc;

use pixelmtj::backend::{
    active_simd, xor_popcount, xor_popcount_scalar, InferScratch,
    InferenceBackend, NativeBackend, NativePath,
};
use pixelmtj::config::{BackendKind, HwConfig, PipelineConfig, SparseCoding};
use pixelmtj::coordinator::Pipeline;
use pixelmtj::sensor::{
    scene::SceneGen, words_for, CaptureMode, FirstLayerWeights, PixelArraySim,
};

fn backend_pair(
    hw: &HwConfig,
    weights: &FirstLayerWeights,
    h: usize,
    w: usize,
    workers: usize,
) -> (NativeBackend, NativeBackend) {
    (
        NativeBackend::new(hw.clone(), weights.clone(), h, w, workers),
        NativeBackend::new(hw.clone(), weights.clone(), h, w, workers)
            .with_path(NativePath::DenseRef),
    )
}

#[test]
fn packed_equals_dense_across_seeds_and_shapes() {
    let hw = HwConfig::default();
    for &(h, w) in &[(16usize, 16usize), (20, 24), (32, 32)] {
        for seed in [1u32, 7, 42] {
            let weights = FirstLayerWeights::synthetic(32, 3, 3, seed);
            let (packed, dense) = backend_pair(&hw, &weights, h, w, 2);
            let gen = SceneGen::new(3, h, w);
            for f in 0..3u32 {
                let frame =
                    gen.textured(seed.wrapping_mul(31).wrapping_add(f));
                let map = packed.run_frontend(&frame).unwrap();
                let act = map.to_f32();
                let a = packed.run_backend(&act, 1).unwrap();
                let b = dense.run_backend(&act, 1).unwrap();
                // The packed entry point (BitPlane words, no widening)
                // must agree with both f32 entries bit for bit.
                let c = packed.run_backend_packed(map.words(), 1).unwrap();
                let d = dense.run_backend_packed(map.words(), 1).unwrap();
                assert_eq!(a, b, "h{h} w{w} seed{seed} frame{f}");
                assert_eq!(a, c, "packed entry h{h} w{w} seed{seed} frame{f}");
                assert_eq!(a, d, "dense packed entry h{h} w{w} seed{seed}");
                assert_eq!(a.len(), packed.num_classes());
                assert!(a.iter().all(|x| x.is_finite()));
                // Logits must actually discriminate (not all equal).
                assert!(a.iter().any(|&x| (x - a[0]).abs() > 1e-6));
            }
        }
    }
}

#[test]
fn frontend_matches_sensor_sim_comparator() {
    let hw = HwConfig::default();
    for seed in [2u32, 9] {
        let weights = FirstLayerWeights::synthetic(32, 3, 3, seed);
        let sim = PixelArraySim::new(hw.clone(), weights.clone());
        let backend = NativeBackend::new(hw.clone(), weights, 32, 32, 1);
        let gen = SceneGen::new(3, 32, 32);
        for f in [3u32, 17, 99] {
            let frame = gen.textured(f);
            let (map, _) = sim.capture(&frame, CaptureMode::Ideal);
            let via_backend = backend.run_frontend(&frame).unwrap();
            assert_eq!(
                map, via_backend,
                "seed {seed} frame {f}: frontend disagrees with sensor sim"
            );
        }
    }
}

#[test]
fn batched_matches_single_frame_runs() {
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 4);
    let backend = NativeBackend::new(hw.clone(), weights.clone(), 32, 32, 4);
    let gen = SceneGen::new(3, 32, 32);
    let elems = backend.act_elems();
    let nc = backend.num_classes();
    let maps: Vec<Vec<f32>> = (0..8u32)
        .map(|i| backend.run_frontend(&gen.textured(i)).unwrap().to_f32())
        .collect();
    let mut batch_buf = Vec::with_capacity(8 * elems);
    for m in &maps {
        batch_buf.extend_from_slice(m);
    }
    let batched = backend.run_backend(&batch_buf, 8).unwrap();
    for (i, m) in maps.iter().enumerate() {
        let single = backend.run_backend(m, 1).unwrap();
        assert_eq!(
            &batched[i * nc..(i + 1) * nc],
            single.as_slice(),
            "frame {i}"
        );
    }
}

#[test]
fn simd_kernel_bit_identical_to_scalar_reference() {
    // Deterministic pseudo-random words over lengths that straddle every
    // SIMD block boundary (AVX2 eats 4 words/iter, NEON 2) plus tails.
    let mut state = 0x243F_6A88_85A3_08D3u64;
    let mut word = || {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
        state ^ (state >> 29)
    };
    for len in 0..=40usize {
        let a: Vec<u64> = (0..len).map(|_| word()).collect();
        let b: Vec<u64> = (0..len).map(|_| word()).collect();
        assert_eq!(
            xor_popcount(&a, &b),
            xor_popcount_scalar(&a, &b),
            "len {len}, dispatched kernel {}",
            active_simd()
        );
    }
}

#[test]
fn simd_model_path_bit_identical_to_scalar_and_dense() {
    // Whole-model three-way parity: SIMD-dispatched batched kernel vs
    // forced-scalar batched kernel vs the dense f32 reference.
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 6);
    let (packed, dense) = backend_pair(&hw, &weights, 24, 24, 1);
    let gen = SceneGen::new(3, 24, 24);
    let model = packed.model();
    let wpf = words_for(model.act_elems());
    let nc = model.num_classes();
    let batch = 5usize;
    let mut words = Vec::with_capacity(batch * wpf);
    for i in 0..batch as u32 {
        let map = packed.run_frontend(&gen.textured(i)).unwrap();
        words.extend_from_slice(map.words());
    }
    let mut scratch = InferScratch::default();
    let mut simd = vec![0.0f32; batch * nc];
    let mut scalar = vec![0.0f32; batch * nc];
    model.infer_batch_words(&words, batch, &mut simd, &mut scratch);
    model.infer_batch_words_scalar(&words, batch, &mut scalar, &mut scratch);
    assert_eq!(simd, scalar, "dispatched ({}) vs scalar", active_simd());
    let via_dense = dense.run_backend_packed(&words, batch).unwrap();
    assert_eq!(simd, via_dense, "batched SIMD vs dense f32 reference");
}

#[test]
fn pipeline_end_to_end_on_native_backend() {
    // The acceptance-criteria flow: no artifacts, no skips.
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 9);
    let cfg = PipelineConfig {
        sparse_coding: SparseCoding::Rle,
        ..PipelineConfig::default()
    };
    assert_eq!(cfg.backend, BackendKind::Native, "native must be the default");
    let sim = PixelArraySim::new(hw.clone(), weights.clone());
    let backend = Arc::new(NativeBackend::new(
        hw,
        weights,
        cfg.sensor_height,
        cfg.sensor_width,
        2,
    ));
    let nc = backend.num_classes();
    let pipeline = Pipeline::new(cfg, sim, backend).unwrap();
    let gen = SceneGen::new(3, 32, 32);
    let frames: Vec<_> = (0..24u32).map(|i| gen.textured(i)).collect();
    let report = pipeline.serve(frames).unwrap();
    assert_eq!(report.results.len(), 24);
    let seqs: Vec<u32> = report.results.iter().map(|r| r.seq).collect();
    assert_eq!(seqs, (0..24).collect::<Vec<_>>(), "results must be ordered");
    assert_eq!(report.metrics.frames_out.get(), 24);
    assert_eq!(report.metrics.frames_dropped.get(), 0);
    for r in &report.results {
        assert_eq!(r.logits.len(), nc);
        assert!(r.logits.iter().all(|x| x.is_finite()));
        assert!(r.label < nc);
        assert!(r.link_bits > 0);
    }
}

#[test]
fn pipeline_native_is_deterministic_across_runs() {
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 13);
    let serve_once = || {
        let cfg = PipelineConfig::default();
        let sim = PixelArraySim::new(hw.clone(), weights.clone());
        let backend = Arc::new(NativeBackend::new(
            hw.clone(),
            weights.clone(),
            cfg.sensor_height,
            cfg.sensor_width,
            3,
        ));
        let pipeline = Pipeline::new(cfg, sim, backend).unwrap();
        let gen = SceneGen::new(3, 32, 32);
        let frames: Vec<_> = (0..16u32).map(|i| gen.textured(i)).collect();
        pipeline.serve(frames).unwrap()
    };
    let a = serve_once();
    let b = serve_once();
    for (x, y) in a.results.iter().zip(b.results.iter()) {
        assert_eq!(x.seq, y.seq);
        assert_eq!(x.logits, y.logits, "seq {}: logits differ", x.seq);
        assert_eq!(x.label, y.label);
        assert_eq!(x.link_bits, y.link_bits);
    }
}
