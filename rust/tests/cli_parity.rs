//! CLI golden-parity and precedence-matrix tests for the registry-driven
//! resolver behind every subcommand (`system::resolve_spec`).
//!
//! Two contracts are pinned here:
//!
//! 1. **Golden parity** — every documented invocation from the USAGE text
//!    (plus the hardening cases from `util/cli.rs`) parses to the same
//!    resolved spec / the same rejection message as the pre-registry
//!    per-subcommand merge code did.
//! 2. **Precedence matrix** — per field and per subcommand, the layer
//!    order is `default < --config file < PIXELMTJ_* env < CLI flag`
//!    with one shared behavior (the serve/sweep drift the redesign
//!    removed), and provenance reports the winning layer.
//!
//! Env layers are injected through `EnvSource::from_pairs` — never via
//! `std::env::set_var` — so these tests stay safe under the parallel
//! test harness.

use pixelmtj::config::{
    BackendKind, Cmd, EnvSource, GeometryPreset, KeyedEnum, Provenance,
    SparseCoding, SweepConfig, Workload,
};
use pixelmtj::system::{resolve_spec, usage, SystemSpec};
use pixelmtj::util::cli::Args;

fn args(line: &str) -> (Cmd, Args) {
    let a = Args::parse(line.split_whitespace().map(String::from)).unwrap();
    let cmd = Cmd::parse(a.command.as_deref().expect("subcommand")).unwrap();
    (cmd, a)
}

fn resolve(line: &str) -> anyhow::Result<SystemSpec> {
    let (cmd, a) = args(line);
    resolve_spec(cmd, &a, &EnvSource::empty())
}

fn resolve_env(
    line: &str,
    env: &[(&str, &str)],
) -> anyhow::Result<SystemSpec> {
    let (cmd, a) = args(line);
    resolve_spec(cmd, &a, &EnvSource::from_pairs(env.iter().copied()))
}

fn tmp_config(name: &str, body: &str) -> String {
    let dir = std::env::temp_dir().join("pixelmtj_cli_parity");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join(name);
    std::fs::write(&p, body).unwrap();
    p.to_string_lossy().into_owned()
}

// ---------------------------------------------------------------------
// Golden parity: documented invocations resolve to the same spec the
// per-subcommand merge code produced.
// ---------------------------------------------------------------------

#[test]
fn bare_serve_resolves_the_documented_defaults() {
    let spec = resolve("serve").unwrap();
    assert_eq!(spec.frames, 256);
    assert!(!spec.streaming);
    assert_eq!(spec.pipeline.sensor_workers, 4);
    assert_eq!(spec.pipeline.queue_depth, 64);
    assert_eq!(spec.pipeline.sparse_coding, SparseCoding::Csr);
    assert_eq!(spec.pipeline.backend, BackendKind::Native);
    assert_eq!(spec.pipeline.workload, Workload::Steady);
    assert!(spec.pipeline.mtj_noise);
    assert_eq!(spec.pipeline.artifacts_dir, "artifacts");
    assert_eq!(
        (spec.pipeline.sensor_height, spec.pipeline.sensor_width),
        (32, 32)
    );
}

#[test]
fn documented_serve_flags_resolve_identically() {
    let spec = resolve(
        "serve --frames 2 --workers 2 --coding rle --backend native \
         --no-mtj-noise --geometry imagenet --artifacts art",
    )
    .unwrap();
    assert_eq!(spec.frames, 2);
    assert_eq!(spec.pipeline.sensor_workers, 2);
    assert_eq!(spec.pipeline.sparse_coding, SparseCoding::Rle);
    assert_eq!(spec.pipeline.backend, BackendKind::Native);
    assert!(!spec.pipeline.mtj_noise);
    assert_eq!(spec.pipeline.geometry, Some(GeometryPreset::ImagenetVgg16));
    assert_eq!(
        (spec.pipeline.sensor_height, spec.pipeline.sensor_width),
        (224, 224)
    );
    assert_eq!(spec.pipeline.artifacts_dir, "art");
}

#[test]
fn documented_stream_invocation_resolves_identically() {
    let spec = resolve(
        "serve --stream --workload bursty --queue-depth 8 --burst-len 4 \
         --burst-gap-us 500",
    )
    .unwrap();
    assert!(spec.streaming);
    assert_eq!(spec.pipeline.workload, Workload::Bursty);
    assert_eq!(spec.pipeline.queue_depth, 8);
    assert_eq!(spec.pipeline.burst_len, 4);
    assert_eq!(spec.pipeline.burst_gap_us, 500);
}

#[test]
fn documented_sweep_invocations_resolve_identically() {
    // The CI sweep smoke invocation.
    let spec = resolve(
        "sweep --grid v=0.8,0.9;k=4,5 --trials 4 --threads 2 --seed 7",
    )
    .unwrap();
    assert_eq!(spec.sweep.grid, "v=0.8,0.9;k=4,5");
    assert_eq!(spec.sweep.trials, 4);
    assert_eq!(spec.sweep.threads, 2);
    assert_eq!(spec.sweep.seed, 7);
    assert_eq!(spec.sweep.out_dir, "reports");

    // The CI imagenet smoke: preset sets dims, explicit flags win.
    let spec =
        resolve("sweep --geometry imagenet --grid v=0.8;k=4,5 --trials 1")
            .unwrap();
    assert_eq!(spec.sweep.geometry, Some(GeometryPreset::ImagenetVgg16));
    assert_eq!(
        (spec.sweep.sensor_height, spec.sweep.sensor_width),
        (224, 224)
    );
    let spec =
        resolve("sweep --geometry imagenet --height 64 --width 48").unwrap();
    assert_eq!(
        (spec.sweep.sensor_height, spec.sweep.sensor_width),
        (64, 48)
    );
}

#[test]
fn documented_report_validate_info_invocations_resolve() {
    let spec = resolve("report all --artifacts a --out o").unwrap();
    assert_eq!(spec.pipeline.artifacts_dir, "a");
    assert_eq!(spec.out_dir, "o");
    let spec = resolve("validate --artifacts a").unwrap();
    assert_eq!(spec.pipeline.artifacts_dir, "a");
    let spec = resolve("info").unwrap();
    assert_eq!(spec.pipeline.artifacts_dir, "artifacts");
}

#[test]
fn config_file_invocations_resolve_identically() {
    let p = tmp_config(
        "serve.json",
        r#"{"sparse_coding": "dense", "queue_depth": 16, "workload": "motion"}"#,
    );
    let spec = resolve(&format!("serve --config {p}")).unwrap();
    assert_eq!(spec.pipeline.sparse_coding, SparseCoding::Dense);
    assert_eq!(spec.pipeline.queue_depth, 16);
    // Ambient profile: stream-only keys are allowed without --stream
    // (the oneshot path prints a notice instead of rejecting).
    assert_eq!(spec.pipeline.workload, Workload::MotionSweep);

    let p = tmp_config("sweep.json", r#"{"grid": "v=0.9;k=5", "trials": 16}"#);
    let spec = resolve(&format!("sweep --config {p} --trials 8")).unwrap();
    assert_eq!(spec.sweep.grid, "v=0.9;k=5", "file layer");
    assert_eq!(spec.sweep.trials, 8, "flag beats file");
}

// ---------------------------------------------------------------------
// Golden parity: rejection messages (the util/cli.rs hardening cases
// plus the per-site bail!s the registry replaced).
// ---------------------------------------------------------------------

#[test]
fn rejection_messages_match_the_pinned_wording() {
    for (line, want) in [
        ("serve --workload motion", "--workload requires --stream"),
        ("serve --burst-len 4", "--burst-len requires --stream"),
        ("serve --burst-gap-us 9", "--burst-gap-us requires --stream"),
        (
            "serve --stream --burst-len 4",
            "--burst-len requires --workload bursty (got steady)",
        ),
        (
            "serve --stream --workload motion --burst-gap-us 9",
            "--burst-gap-us requires --workload bursty (got motion)",
        ),
        ("serve --grid v=0.8 --frames 2", "unknown option --grid"),
        ("report fig5 --trials 8", "unknown option --trials"),
        ("sweep --threads8 --grid v=0.8", "unknown flag --threads8"),
        ("sweep --grid --trials 4", "--grid expects a value"),
        (
            "serve --stream 64",
            "--stream is a flag and takes no value (got \"64\")",
        ),
        ("serve --frames abc", "--frames expects an integer, got \"abc\""),
        (
            "serve --coding zip",
            "unknown sparse coding 'zip' (expected 'dense', 'csr' or 'rle')",
        ),
        (
            "serve --backend tpu",
            "unknown backend 'tpu' (expected 'native' or 'pjrt')",
        ),
        (
            "sweep --geometry mnist",
            "unknown geometry 'mnist' (expected 'cifar' or 'imagenet')",
        ),
        (
            "serve --workload spiky",
            "unknown workload 'spiky' (expected 'steady', 'bursty' or 'motion')",
        ),
        ("sweep --artifacts x", "unknown option --artifacts"),
        ("validate --grid v=0.8", "unknown option --grid"),
        ("info --config x.json", "unknown option --config"),
    ] {
        let err = resolve(line).unwrap_err();
        assert_eq!(format!("{err}"), want, "{line}");
    }
}

#[test]
fn cross_flag_rules_do_not_fire_for_ambient_layers() {
    // workload from env: allowed without --stream (ambient profile).
    let spec =
        resolve_env("serve", &[("PIXELMTJ_WORKLOAD", "motion")]).unwrap();
    assert_eq!(spec.pipeline.workload, Workload::MotionSweep);
    assert!(!spec.streaming);
    // --workload with env-provided stream: the explicit flag is fine
    // because streaming is on, wherever `stream` came from.
    let spec = resolve_env(
        "serve --workload motion",
        &[("PIXELMTJ_STREAM", "1")],
    )
    .unwrap();
    assert!(spec.streaming);
    assert_eq!(spec.provenance("stream"), Provenance::Env);
}

// ---------------------------------------------------------------------
// Precedence matrix: default vs file vs env vs flag, per field, per
// subcommand — one shared behavior after the redesign.
// ---------------------------------------------------------------------

#[test]
fn precedence_matrix_serve_fields() {
    let file = tmp_config(
        "prec_serve.json",
        r#"{"sparse_coding": "dense", "backend": "pjrt",
            "sensor_workers": 3, "geometry": "imagenet"}"#,
    );
    let with_file = format!("serve --config {file}");
    let env = &[
        ("PIXELMTJ_CODING", "rle"),
        ("PIXELMTJ_BACKEND", "native"),
        ("PIXELMTJ_WORKERS", "5"),
        ("PIXELMTJ_GEOMETRY", "cifar"),
    ][..];

    // default
    let s = resolve("serve").unwrap();
    assert_eq!(s.pipeline.sparse_coding, SparseCoding::Csr);
    assert_eq!(s.provenance("coding"), Provenance::Default);

    // file beats default
    let s = resolve(&with_file).unwrap();
    assert_eq!(s.pipeline.sparse_coding, SparseCoding::Dense);
    assert_eq!(s.pipeline.backend, BackendKind::Pjrt);
    assert_eq!(s.pipeline.sensor_workers, 3);
    assert_eq!(s.pipeline.geometry, Some(GeometryPreset::ImagenetVgg16));
    assert_eq!(s.pipeline.sensor_height, 224);
    for f in ["coding", "backend", "workers", "geometry", "height"] {
        assert_eq!(s.provenance(f), Provenance::File, "{f}");
    }

    // env beats file
    let s = resolve_env(&with_file, env).unwrap();
    assert_eq!(s.pipeline.sparse_coding, SparseCoding::Rle);
    assert_eq!(s.pipeline.backend, BackendKind::Native);
    assert_eq!(s.pipeline.sensor_workers, 5);
    assert_eq!(s.pipeline.geometry, Some(GeometryPreset::Cifar));
    assert_eq!(s.pipeline.sensor_height, 32);
    for f in ["coding", "backend", "workers", "geometry"] {
        assert_eq!(s.provenance(f), Provenance::Env, "{f}");
    }

    // flag beats env beats file
    let s = resolve_env(
        &format!(
            "{with_file} --coding dense --backend pjrt --workers 9 \
             --geometry imagenet"
        ),
        env,
    )
    .unwrap();
    assert_eq!(s.pipeline.sparse_coding, SparseCoding::Dense);
    assert_eq!(s.pipeline.backend, BackendKind::Pjrt);
    assert_eq!(s.pipeline.sensor_workers, 9);
    assert_eq!(s.pipeline.geometry, Some(GeometryPreset::ImagenetVgg16));
    assert_eq!(s.pipeline.sensor_height, 224);
    for f in ["coding", "backend", "workers", "geometry"] {
        assert_eq!(s.provenance(f), Provenance::Cli, "{f}");
    }
}

#[test]
fn precedence_matrix_sweep_fields_share_the_serve_behavior() {
    let file = tmp_config(
        "prec_sweep.json",
        r#"{"grid": "v=0.9", "trials": 10, "threads": 3,
            "geometry": "imagenet", "out_dir": "file_out"}"#,
    );
    let with_file = format!("sweep --config {file}");
    let env = &[
        ("PIXELMTJ_GRID", "v=0.7"),
        ("PIXELMTJ_TRIALS", "20"),
        ("PIXELMTJ_OUT", "env_out"),
    ][..];

    let s = resolve("sweep").unwrap();
    assert_eq!(s.sweep.grid, SweepConfig::default().grid);
    assert_eq!(s.provenance("grid"), Provenance::Default);

    let s = resolve(&with_file).unwrap();
    assert_eq!(s.sweep.grid, "v=0.9");
    assert_eq!(s.sweep.trials, 10);
    assert_eq!(s.sweep.out_dir, "file_out");
    assert_eq!(s.sweep.sensor_height, 224);
    assert_eq!(s.provenance("grid"), Provenance::File);

    let s = resolve_env(&with_file, env).unwrap();
    assert_eq!(s.sweep.grid, "v=0.7");
    assert_eq!(s.sweep.trials, 20);
    assert_eq!(s.sweep.out_dir, "env_out");
    assert_eq!(s.provenance("out"), Provenance::Env);

    let s = resolve_env(
        &format!("{with_file} --grid v=0.8 --trials 5 --out cli_out"),
        env,
    )
    .unwrap();
    assert_eq!(s.sweep.grid, "v=0.8");
    assert_eq!(s.sweep.trials, 5);
    assert_eq!(s.sweep.out_dir, "cli_out");
    assert_eq!(s.provenance("grid"), Provenance::Cli);
    // Threads untouched by env/cli: file survives as the winner.
    assert_eq!(s.sweep.threads, 3);
    assert_eq!(s.provenance("threads"), Provenance::File);
}

#[test]
fn wire_scale_flags_resolve_and_gate() {
    // --max-sessions is serve-only and stream-gated, with the full
    // default < file < env < cli stack behind it.
    let s = resolve("serve --stream --max-sessions 32").unwrap();
    assert_eq!(s.pipeline.max_sessions, 32);
    assert_eq!(s.provenance("max-sessions"), Provenance::Cli);

    let s = resolve("serve").unwrap();
    assert_eq!(s.pipeline.max_sessions, 8, "the documented session cap");
    assert_eq!(s.provenance("max-sessions"), Provenance::Default);

    let file = tmp_config("wire_scale.json", r#"{"max_sessions": 12}"#);
    let s = resolve(&format!("serve --stream --config {file}")).unwrap();
    assert_eq!(s.pipeline.max_sessions, 12);
    assert_eq!(s.provenance("max-sessions"), Provenance::File);

    let s = resolve_env(
        &format!("serve --stream --config {file}"),
        &[("PIXELMTJ_MAX_SESSIONS", "24")],
    )
    .unwrap();
    assert_eq!(s.pipeline.max_sessions, 24, "env beats file");
    assert_eq!(s.provenance("max-sessions"), Provenance::Env);

    // The push load-driver flags resolve with Cli provenance and sane
    // defaults (one frame per envelope, one session).
    let s = resolve(
        "push --connect 127.0.0.1:9 --batch-frames 8 --sessions 4",
    )
    .unwrap();
    assert_eq!(s.push_batch_frames, 8);
    assert_eq!(s.push_sessions, 4);
    for f in ["batch-frames", "sessions"] {
        assert_eq!(s.provenance(f), Provenance::Cli, "{f}");
    }
    let s = resolve("push --connect 127.0.0.1:9").unwrap();
    assert_eq!((s.push_batch_frames, s.push_sessions), (1, 1));

    // Each flag stays inside its subcommand.
    for (line, want) in [
        ("serve --max-sessions 4", "--max-sessions requires --stream"),
        (
            "push --connect x --max-sessions 4",
            "unknown option --max-sessions",
        ),
        ("serve --batch-frames 8", "unknown option --batch-frames"),
        ("sweep --sessions 4", "unknown option --sessions"),
    ] {
        let err = resolve(line).unwrap_err();
        assert_eq!(format!("{err}"), want, "{line}");
    }
}

#[test]
fn one_config_file_serves_both_subcommands() {
    // The unified file layer: pipeline and sweep keys in one profile,
    // each subcommand picking up its half (unknown keys ignored).
    let file = tmp_config(
        "prec_both.json",
        r#"{"sparse_coding": "dense", "grid": "v=0.9;k=5",
            "sensor_height": 64}"#,
    );
    let s = resolve(&format!("serve --config {file}")).unwrap();
    assert_eq!(s.pipeline.sparse_coding, SparseCoding::Dense);
    assert_eq!(s.pipeline.sensor_height, 64);
    let s = resolve(&format!("sweep --config {file}")).unwrap();
    assert_eq!(s.sweep.grid, "v=0.9;k=5");
    assert_eq!(s.sweep.sensor_height, 64);
}

#[test]
fn env_config_names_the_file_layer() {
    let file = tmp_config("env_named.json", r#"{"queue_depth": 5}"#);
    let s = resolve_env("serve", &[("PIXELMTJ_CONFIG", file.as_str())])
        .unwrap();
    assert_eq!(s.pipeline.queue_depth, 5);
    assert_eq!(s.provenance("config"), Provenance::Env);
    assert_eq!(s.config_path.as_deref(), Some(file.as_str()));
    // The env spelling is ambient: it names the profile even for
    // subcommands whose CLI does not take --config.
    let s = resolve_env("report all", &[("PIXELMTJ_CONFIG", file.as_str())])
        .unwrap();
    assert_eq!(s.pipeline.queue_depth, 5);
}

#[test]
fn file_out_dir_reaches_both_report_and_sweep_sinks() {
    let file = tmp_config("out_sync.json", r#"{"out_dir": "campaign_out"}"#);
    let s = resolve(&format!("sweep --config {file}")).unwrap();
    assert_eq!(s.sweep.out_dir, "campaign_out");
    assert_eq!(s.out_dir, "campaign_out", "report sink follows the file");
    assert_eq!(s.provenance("out"), Provenance::File);
}

#[test]
fn missing_config_file_fails_with_the_documented_context() {
    let err = resolve("serve --config /nonexistent/x.json").unwrap_err();
    assert!(
        format!("{err}").starts_with("loading pipeline config"),
        "{err}"
    );
    let err = resolve("sweep --config /nonexistent/x.json").unwrap_err();
    assert!(format!("{err}").starts_with("loading sweep config"), "{err}");
}

#[test]
fn usage_documents_every_subcommand_and_flag() {
    let u = usage();
    for cmd in ["serve", "report", "sweep", "validate", "info", "config"] {
        assert!(u.contains(&format!("pixelmtj {cmd}")), "{cmd}\n{u}");
    }
    for flag in [
        "--frames", "--workers", "--coding", "--backend", "--no-mtj-noise",
        "--geometry", "--artifacts", "--config", "--stream", "--workload",
        "--queue-depth", "--burst-len", "--burst-gap-us", "--grid",
        "--trials", "--threads", "--seed", "--height", "--width", "--out",
        "--max-sessions", "--batch-frames", "--sessions",
    ] {
        assert!(u.contains(flag), "{flag}\n{u}");
    }
    assert!(u.contains("<id|all>"));
    assert!(u.contains("PIXELMTJ_"));
}
