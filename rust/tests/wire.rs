//! Wire front-door integration tests, in three layers:
//!
//! * **Spec honesty** — docs/PROTOCOL.md is parsed and its normative
//!   tables (message types, status codes, frame codings) are compared
//!   against the protocol constants; the worked hex examples are decoded
//!   byte for byte.  If the spec and the code disagree, these fail.
//! * **Loopback parity** — a real `System::serve_wire` listener on an
//!   ephemeral port, driven by `WireClient` in every coding, must
//!   classify identically to in-process `Pipeline::serve` of the same
//!   frames.
//! * **Hostility** — malformed probes (bad magic, bad version, bad
//!   geometry, wrong first message, coding mismatch) must each earn the
//!   documented typed `ERROR` and land in the per-code metric.
//!
//! All on the native backend with synthetic weights, so nothing skips.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use pixelmtj::config::{HwConfig, PipelineConfig, WireCoding};
use pixelmtj::sensor::{scene::SceneGen, Frame};
use pixelmtj::system::{System, WireService};
use pixelmtj::wire::proto::{self, CODINGS, MESSAGE_TYPES};
use pixelmtj::wire::{LeaseState, Msg, StatusCode, WireClient};

mod common;
use common::native_pipeline;

const DOC: &str = include_str!("../../docs/PROTOCOL.md");

// ---------------------------------------------------------------------
// Spec honesty: the document is normative, the constants must match it.
// ---------------------------------------------------------------------

/// The slice of `DOC` between `header` and the next `## ` heading.
fn section<'a>(doc: &'a str, header: &str) -> &'a str {
    let start = doc
        .find(header)
        .unwrap_or_else(|| panic!("PROTOCOL.md lost its {header:?} section"));
    let rest = &doc[start + header.len()..];
    match rest.find("\n## ") {
        Some(end) => &rest[..end],
        None => rest,
    }
}

/// Markdown table rows as cell vectors, header and `---` rows dropped.
fn table_rows(section: &str) -> Vec<Vec<String>> {
    section
        .lines()
        .map(str::trim)
        .filter(|l| l.starts_with('|'))
        .map(|l| {
            l.trim_matches('|')
                .split('|')
                .map(|c| c.trim().to_string())
                .collect::<Vec<String>>()
        })
        .filter(|cells| {
            let first = cells.first().map(String::as_str).unwrap_or("");
            !first.is_empty()
                && !first.chars().all(|ch| ch == '-')
                && first
                    .trim_start_matches("0x")
                    .chars()
                    .all(|ch| ch.is_ascii_hexdigit())
        })
        .collect()
}

#[test]
fn protocol_doc_tables_match_the_wire_constants() {
    // Message types: `| 0x01 | HELLO | ... |` rows.
    let mut documented: Vec<(u8, String)> =
        table_rows(section(DOC, "## Message types"))
            .iter()
            .map(|cells| {
                let byte =
                    u8::from_str_radix(cells[0].trim_start_matches("0x"), 16)
                        .unwrap_or_else(|_| {
                            panic!("bad type byte cell {:?}", cells[0])
                        });
                (byte, cells[1].clone())
            })
            .collect();
    documented.sort_unstable();
    let mut in_code: Vec<(u8, String)> = MESSAGE_TYPES
        .iter()
        .map(|(b, n)| (*b, n.to_string()))
        .collect();
    in_code.sort_unstable();
    assert_eq!(documented, in_code, "message-type table drifted");

    // Status codes: `| 0 | ok | ... |` rows, names doubling as the
    // metric's `code` label values.
    let documented: Vec<(u8, String)> =
        table_rows(section(DOC, "## Status codes"))
            .iter()
            .map(|cells| (cells[0].parse::<u8>().unwrap(), cells[1].clone()))
            .collect();
    let in_code: Vec<(u8, String)> = StatusCode::ALL
        .iter()
        .map(|c| (c.byte(), c.name().to_string()))
        .collect();
    assert_eq!(documented, in_code, "status-code table drifted");

    // Frame codings: `| 0 | f32 | ... |` rows.
    let documented: Vec<(u8, String)> =
        table_rows(section(DOC, "## Frame codings"))
            .iter()
            .map(|cells| (cells[0].parse::<u8>().unwrap(), cells[1].clone()))
            .collect();
    let in_code: Vec<(u8, String)> =
        CODINGS.iter().map(|(b, n)| (*b, n.to_string())).collect();
    assert_eq!(documented, in_code, "frame-coding table drifted");

    // Envelope facts quoted in prose: version, magic, payload cap.
    assert!(
        DOC.contains(&format!("(version {})", proto::VERSION)),
        "title must name protocol version {}",
        proto::VERSION
    );
    let magic_hex = proto::MAGIC
        .iter()
        .map(|b| format!("{b:02X}"))
        .collect::<Vec<_>>()
        .join(" ");
    assert!(DOC.contains(&magic_hex), "magic bytes {magic_hex} missing");
    assert!(
        DOC.contains(&proto::MAX_PAYLOAD.to_string()),
        "payload cap {} missing",
        proto::MAX_PAYLOAD
    );
}

/// Hex dumps inside a section's code fences: leading two-hex-digit
/// tokens per line, stopping at the first prose token.  Only blocks
/// that open with the envelope magic count as worked examples — the
/// byte-layout tables share the same fence style, and their decimal
/// offset columns (`12`, `16`, …) would otherwise parse as hex.
fn hex_blocks(section: &str) -> Vec<Vec<u8>> {
    let mut blocks = Vec::new();
    let mut current: Option<Vec<u8>> = None;
    for line in section.lines() {
        if line.trim_start().starts_with("```") {
            match current.take() {
                Some(block) => blocks.push(block),
                None => current = Some(Vec::new()),
            }
            continue;
        }
        if let Some(block) = current.as_mut() {
            for token in line.split_whitespace() {
                match u8::from_str_radix(token, 16) {
                    Ok(byte) if token.len() == 2 => block.push(byte),
                    _ => break,
                }
            }
        }
    }
    blocks.retain(|b| b.starts_with(&proto::MAGIC));
    blocks
}

#[test]
fn protocol_doc_worked_examples_decode_byte_for_byte() {
    let blocks = hex_blocks(section(DOC, "## Worked example"));
    assert_eq!(blocks.len(), 2, "the spec shows two worked examples");

    let (hello, used) = proto::decode(&blocks[0]).expect("HELLO example");
    assert_eq!(used, blocks[0].len(), "no trailing bytes in the example");
    assert_eq!(
        hello,
        Msg::Hello {
            version: 1,
            coding: WireCoding::Csr,
            channels: 3,
            height: 32,
            width: 32,
        }
    );

    let (result, used) = proto::decode(&blocks[1]).expect("RESULT example");
    assert_eq!(used, blocks[1].len());
    assert_eq!(
        result,
        Msg::Result { seq: 7, trace_id: 0x1234_5678_9abc_def0, label: 2 }
    );
}

#[test]
fn protocol_doc_v2_batch_examples_decode_byte_for_byte() {
    let v2 = section(DOC, "## Protocol v2");
    assert!(
        v2.contains("FRAME_BATCH") && v2.contains("RESULT_BATCH"),
        "the v2 section must document both batch envelopes"
    );
    assert!(
        DOC.contains(&format!("version {}", proto::VERSION_V2)),
        "the spec must name negotiated version {}",
        proto::VERSION_V2
    );

    let blocks = hex_blocks(v2);
    assert_eq!(blocks.len(), 2, "the v2 spec shows one batch each way");

    let (batch, used) =
        proto::decode(&blocks[0]).expect("FRAME_BATCH example");
    assert_eq!(used, blocks[0].len(), "no trailing bytes in the example");
    assert_eq!(
        batch,
        Msg::FrameBatch {
            first_seq: 7,
            coding: WireCoding::Dense,
            bodies: vec![vec![0xaa, 0xbb, 0xcc], vec![0xff]],
        }
    );

    let (results, used) =
        proto::decode(&blocks[1]).expect("RESULT_BATCH example");
    assert_eq!(used, blocks[1].len());
    assert_eq!(
        results,
        Msg::ResultBatch { results: vec![(7, 1, 2), (8, 2, 0)] }
    );
}

#[test]
fn protocol_doc_campaign_examples_decode_byte_for_byte() {
    let sec = section(DOC, "## Campaign channel");
    for msg in ["CAMPAIGN_HELLO", "CAMPAIGN_WELCOME", "LEASE_REQUEST",
                "LEASE_GRANT", "CELL_RESULT"] {
        assert!(
            sec.contains(msg),
            "the campaign section must document {msg}"
        );
    }
    assert!(
        sec.contains(&format!("this spec: {}", proto::CAMPAIGN_VERSION)),
        "the campaign section must name campaign version {}",
        proto::CAMPAIGN_VERSION
    );

    let blocks = hex_blocks(sec);
    assert_eq!(blocks.len(), 2, "the campaign spec shows a hello and a grant");

    let (hello, used) =
        proto::decode(&blocks[0]).expect("CAMPAIGN_HELLO example");
    assert_eq!(used, blocks[0].len(), "no trailing bytes in the example");
    assert_eq!(
        hello,
        Msg::CampaignHello {
            version: proto::CAMPAIGN_VERSION,
            lease_cells: 4,
        }
    );

    let (grant, used) =
        proto::decode(&blocks[1]).expect("LEASE_GRANT example");
    assert_eq!(used, blocks[1].len());
    assert_eq!(
        grant,
        Msg::LeaseGrant {
            state: LeaseState::Granted,
            lease_id: 1,
            start: 4,
            count: 2,
            retry_ms: 0,
        }
    );
}

#[test]
fn every_documented_message_type_roundtrips() {
    let msgs = vec![
        Msg::Hello {
            version: proto::VERSION,
            coding: WireCoding::Rle,
            channels: 3,
            height: 32,
            width: 32,
        },
        Msg::HelloAck {
            version: proto::VERSION,
            max_inflight: 64,
            queue_depth: 64,
        },
        Msg::Frame {
            seq: 41,
            coding: WireCoding::Dense,
            body: vec![0xaa; 24],
        },
        Msg::Result { seq: 41, trace_id: 99, label: 7 },
        Msg::Goodbye { code: StatusCode::Ok },
        Msg::Error {
            code: StatusCode::BadGeometry,
            detail: "server geometry is 3x32x32".to_string(),
        },
        Msg::FrameBatch {
            first_seq: 42,
            coding: WireCoding::Csr,
            bodies: vec![vec![1, 2, 3], Vec::new(), vec![0xff; 9]],
        },
        Msg::ResultBatch {
            results: vec![(42, 7, 0), (43, 8, 5), (44, 9, 1)],
        },
        Msg::CampaignHello {
            version: proto::CAMPAIGN_VERSION,
            lease_cells: 4,
        },
        Msg::CampaignWelcome {
            trials: 6,
            seed: 42,
            height: 24,
            width: 24,
            grid: "v=0.7,0.8,0.9;pulse=0.7;n=8;k=5".to_string(),
            geometry: String::new(),
        },
        Msg::LeaseRequest,
        Msg::LeaseGrant {
            state: LeaseState::Wait,
            lease_id: 0,
            start: 0,
            count: 0,
            retry_ms: 200,
        },
        Msg::CellResult {
            lease_id: 9,
            index: 5,
            trials: 6,
            elements_per_frame: 4608,
            ber: 0.015625,
            e10: 0.25,
            e01: 0.0,
            agreement: 0.96875,
            mean_sparsity: 0.5,
            energy_pj_per_frame: 12.75,
        },
    ];
    // One sample per documented type byte — no type left untested.
    let mut seen: Vec<u8> = msgs.iter().map(Msg::type_byte).collect();
    seen.sort_unstable();
    let mut want: Vec<u8> = MESSAGE_TYPES.iter().map(|(b, _)| *b).collect();
    want.sort_unstable();
    assert_eq!(seen, want);
    for msg in msgs {
        let bytes = msg.encode();
        let (back, used) = proto::decode(&bytes).unwrap();
        assert_eq!(back, msg);
        assert_eq!(used, bytes.len());
    }
}

// ---------------------------------------------------------------------
// Loopback parity: the wire path classifies exactly like in-process.
// ---------------------------------------------------------------------

fn listening_system() -> (System, usize, usize, usize) {
    let mut sys = System::builder()
        .artifacts_dir("/nonexistent")
        .workers(2)
        .listen("127.0.0.1:0")
        .build();
    let channels = HwConfig::default().network.in_channels;
    let (height, width) = (
        sys.spec().pipeline.sensor_height,
        sys.spec().pipeline.sensor_width,
    );
    (sys, channels, height, width)
}

fn textured_frames(n: u32, c: usize, h: usize, w: usize) -> Vec<Frame> {
    let gen = SceneGen::new(c, h, w);
    (0..n).map(|i| gen.textured(i)).collect()
}

/// The frame an in-process caller would submit to match a packed wire
/// coding: binarized at the same 0.5 threshold as `pack_f32`.
fn thresholded(frame: &Frame) -> Frame {
    let data = frame
        .data
        .iter()
        .map(|v| if *v > 0.5 { 1.0 } else { 0.0 })
        .collect();
    Frame::from_data(frame.channels, frame.height, frame.width, data, frame.seq)
        .expect("thresholding preserves geometry")
}

/// Wait for the last session thread to release its slot — the client's
/// closing GOODBYE races the server-side guard drop by a few µs.
fn await_quiescent(svc: &WireService) {
    let deadline = Instant::now() + Duration::from_secs(30);
    while svc.metrics.sessions_active() != 0 {
        assert!(Instant::now() < deadline, "session never released its slot");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn wire_serving_matches_in_process_serving_across_codings() {
    const N: u32 = 10;
    let (mut sys, channels, height, width) = listening_system();
    let mut svc = sys.serve_wire().unwrap();
    assert!(svc.health.ready().is_ok(), "listening server must be ready");
    let addr = svc.server.local_addr().to_string();
    let frames = textured_frames(N, channels, height, width);

    // The in-process references: raw frames (what an f32 session ships)
    // and thresholded frames (what the packed codings reconstruct).
    let raw_ref = native_pipeline(PipelineConfig::default())
        .serve(frames.clone())
        .unwrap();
    let packed_ref = native_pipeline(PipelineConfig::default())
        .serve(frames.iter().map(thresholded).collect())
        .unwrap();

    for coding in [
        WireCoding::F32,
        WireCoding::Dense,
        WireCoding::Csr,
        WireCoding::Rle,
    ] {
        let mut client =
            WireClient::connect(&addr, coding, channels, height, width)
                .unwrap();
        assert_eq!(
            client.max_inflight(),
            client.queue_depth().max(1),
            "the credit window is the advertised queue share"
        );
        for frame in &frames {
            client.send_frame(frame).unwrap();
        }
        let results = client.finish().unwrap();
        assert_eq!(results.len(), N as usize, "{coding:?}: one RESULT each");

        let reference = match coding {
            WireCoding::F32 => &raw_ref,
            _ => &packed_ref,
        };
        for (wire, local) in results.iter().zip(reference.results.iter()) {
            assert_eq!(wire.seq, local.seq, "{coding:?}: seq order");
            assert_eq!(
                wire.label, local.label,
                "{coding:?}: wire seq {} classified differently from the \
                 in-process pipeline",
                wire.seq
            );
        }
        let ids: std::collections::BTreeSet<u64> =
            results.iter().map(|r| r.trace_id).collect();
        assert_eq!(ids.len(), N as usize, "{coding:?}: distinct trace ids");
    }

    await_quiescent(&svc);
    assert_eq!(svc.metrics.sessions_total.get(), 4);
    assert_eq!(svc.metrics.frames_received.get(), 4 * N as u64);
    assert_eq!(svc.metrics.results_sent.get(), 4 * N as u64);
    assert_eq!(svc.metrics.queue_rejections.get(), 0);
    assert_eq!(svc.metrics.session_rejections.get(), 0);
    for code in StatusCode::ALL {
        assert_eq!(
            svc.metrics.protocol_error_count(*code),
            0,
            "clean sessions must not count {} errors",
            code.name()
        );
    }

    // Shutdown flips readiness exactly like the in-process stream does.
    svc.server.shutdown();
    let err = svc.health.ready().expect_err("stopped server is not ready");
    assert!(format!("{err:#}").contains("stream stopped"), "{err:#}");
}

#[test]
fn v2_batched_session_classifies_identically_and_cuts_envelopes() {
    const N: u32 = 12;
    let (mut sys, channels, height, width) = listening_system();
    let mut svc = sys.serve_wire().unwrap();
    let addr = svc.server.local_addr().to_string();
    let frames = textured_frames(N, channels, height, width);

    // The v1 per-frame session: reference labels and envelope count.
    let mut v1 =
        WireClient::connect(&addr, WireCoding::Csr, channels, height, width)
            .unwrap();
    assert_eq!(v1.version(), proto::VERSION);
    for frame in &frames {
        v1.send_frame(frame).unwrap();
    }
    let v1_envelopes = v1.envelopes_sent();
    let v1_results = v1.finish().unwrap();
    assert_eq!(v1_results.len(), N as usize);

    // The same frames over a v2 session, 8 per FRAME_BATCH envelope.
    let mut v2 = WireClient::connect_versioned(
        &addr,
        proto::VERSION_V2,
        WireCoding::Csr,
        channels,
        height,
        width,
    )
    .unwrap();
    assert_eq!(v2.version(), proto::VERSION_V2);
    for chunk in frames.chunks(8) {
        v2.send_batch(chunk).unwrap();
    }
    let v2_envelopes = v2.envelopes_sent();
    let v2_results = v2.finish().unwrap();
    assert_eq!(v2_results.len(), N as usize);

    for (a, b) in v1_results.iter().zip(v2_results.iter()) {
        assert_eq!(a.seq, b.seq, "batched sessions preserve seq order");
        assert_eq!(
            a.label, b.label,
            "batched seq {} classified differently from per-frame v1",
            a.seq
        );
    }
    assert!(
        v2_envelopes < v1_envelopes,
        "batching must cut the envelope count ({v2_envelopes} vs \
         {v1_envelopes})"
    );

    // A v1 session shipping the v2-only type byte is a protocol error:
    // batching exists only once HELLO negotiated version 2.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream
        .write_all(
            &Msg::Hello {
                version: proto::VERSION,
                coding: WireCoding::Csr,
                channels: channels as u16,
                height: height as u32,
                width: width as u32,
            }
            .encode(),
        )
        .unwrap();
    match read_one(&mut stream) {
        Msg::HelloAck { version, .. } => assert_eq!(version, proto::VERSION),
        other => panic!("expected HELLO_ACK, got {other:?}"),
    }
    stream
        .write_all(
            &Msg::FrameBatch {
                first_seq: 0,
                coding: WireCoding::Csr,
                bodies: vec![Vec::new()],
            }
            .encode(),
        )
        .unwrap();
    match read_one(&mut stream) {
        Msg::Error { code, detail } => {
            assert_eq!(code, StatusCode::BadMessage);
            assert!(detail.contains("0x07"), "{detail}");
        }
        other => panic!("expected ERROR, got {other:?}"),
    }
    drop(stream);

    await_quiescent(&svc);
    assert_eq!(svc.metrics.frames_received.get(), 2 * N as u64);
    assert_eq!(svc.metrics.results_sent.get(), 2 * N as u64);
    assert_eq!(
        svc.metrics.protocol_error_count(StatusCode::BadMessage),
        1,
        "only the premature FRAME_BATCH errored"
    );
    svc.server.shutdown();
}

#[test]
fn client_rejects_geometry_mismatch_before_sending() {
    let (mut sys, channels, height, width) = listening_system();
    let mut svc = sys.serve_wire().unwrap();
    let addr = svc.server.local_addr().to_string();

    let mut client = WireClient::connect(
        &addr,
        WireCoding::Dense,
        channels,
        height,
        width,
    )
    .unwrap();
    let err = client
        .send_frame(&Frame::new(channels, height + 1, width, 0))
        .expect_err("a mis-sized frame must fail client-side");
    assert!(
        format!("{err:#}").contains("session negotiated"),
        "{err:#}"
    );
    // Nothing hit the wire: dropping the client is a silent probe, not
    // a protocol error.
    drop(client);
    await_quiescent(&svc);
    assert_eq!(svc.metrics.protocol_error_count(StatusCode::BadFrame), 0);
    assert_eq!(svc.metrics.frames_received.get(), 0);
    svc.server.shutdown();
}

// ---------------------------------------------------------------------
// Hostility: every malformed probe earns its documented typed ERROR.
// ---------------------------------------------------------------------

/// Fire raw bytes at the server and decode the reply, which must be a
/// single terminal `ERROR` before the server closes the connection.
fn probe(addr: &str, bytes: &[u8]) -> (StatusCode, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(bytes).unwrap();
    let mut reply = Vec::new();
    stream.read_to_end(&mut reply).unwrap();
    let (msg, _) = proto::decode(&reply)
        .unwrap_or_else(|e| panic!("expected an ERROR reply, got {e}"));
    match msg {
        Msg::Error { code, detail } => (code, detail),
        other => panic!("expected ERROR, got {other:?}"),
    }
}

/// Read one server message off a hand-driven socket, with a deadline so
/// a wedged server fails the test instead of hanging it.
fn read_one(stream: &mut TcpStream) -> Msg {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let deadline = Instant::now() + Duration::from_secs(30);
    let overdue = move || Instant::now() > deadline;
    match proto::read_msg(stream, &overdue) {
        Ok(proto::MsgOutcome::Msg(m)) => m,
        other => panic!("expected a message, got {other:?}"),
    }
}

#[test]
fn malformed_probes_get_typed_errors_and_are_counted() {
    let (mut sys, channels, height, width) = listening_system();
    let mut svc = sys.serve_wire().unwrap();
    let addr = svc.server.local_addr().to_string();

    // Envelope-sized bytes that are not "PXMJ...": bad_magic.
    let (code, detail) = probe(&addr, b"GET / HTT");
    assert_eq!(code, StatusCode::BadMagic, "{detail}");

    // A well-formed HELLO asking for a version this build cannot speak.
    let hello = |version: u16, c: usize, h: usize, w: usize| {
        Msg::Hello {
            version,
            coding: WireCoding::Dense,
            channels: c as u16,
            height: h as u32,
            width: w as u32,
        }
        .encode()
    };
    let (code, detail) =
        probe(&addr, &hello(99, channels, height, width));
    assert_eq!(code, StatusCode::BadVersion);
    assert!(
        detail.contains(&format!("version {}", proto::VERSION)),
        "rejection must name the served version: {detail}"
    );

    // Valid version, wrong geometry.
    let (code, detail) =
        probe(&addr, &hello(proto::VERSION, channels + 2, height, width));
    assert_eq!(code, StatusCode::BadGeometry);
    assert!(
        detail.contains(&format!("{channels}x{height}x{width}")),
        "rejection must name the serving geometry: {detail}"
    );

    // A first message that is not HELLO.
    let (code, detail) =
        probe(&addr, &Msg::Goodbye { code: StatusCode::Ok }.encode());
    assert_eq!(code, StatusCode::BadMessage);
    assert!(detail.contains("HELLO"), "{detail}");

    // A negotiated session whose FRAME carries the wrong coding byte:
    // full handshake first, then the violation mid-session.
    let mut stream = TcpStream::connect(&addr).unwrap();
    stream
        .write_all(&hello(proto::VERSION, channels, height, width))
        .unwrap();
    match read_one(&mut stream) {
        Msg::HelloAck { version, .. } => assert_eq!(version, proto::VERSION),
        other => panic!("expected HELLO_ACK, got {other:?}"),
    }
    stream
        .write_all(
            &Msg::Frame { seq: 0, coding: WireCoding::F32, body: Vec::new() }
                .encode(),
        )
        .unwrap();
    match read_one(&mut stream) {
        Msg::Error { code, detail } => {
            assert_eq!(code, StatusCode::BadFrame);
            assert!(detail.contains("coding"), "{detail}");
        }
        other => panic!("expected ERROR, got {other:?}"),
    }
    drop(stream);

    // Every probe landed under its documented code, nothing else moved.
    await_quiescent(&svc);
    let counts: Vec<(&str, u64)> = StatusCode::ALL
        .iter()
        .map(|c| (c.name(), svc.metrics.protocol_error_count(*c)))
        .collect();
    assert_eq!(
        counts,
        vec![
            ("ok", 0),
            ("bad_magic", 1),
            ("bad_version", 1),
            ("bad_message", 1),
            ("bad_geometry", 1),
            ("bad_frame", 1),
            ("overloaded", 0),
            ("internal", 0),
            ("shutting_down", 0),
        ]
    );
    // Only the fully negotiated session ever held a slot; no frame was
    // accepted, so no result was produced.
    assert_eq!(svc.metrics.sessions_total.get(), 1);
    assert_eq!(svc.metrics.frames_received.get(), 0);
    assert_eq!(svc.metrics.results_sent.get(), 0);
    svc.server.shutdown();
}
