//! Distributed campaign integration: coordinator + workers over
//! loopback, interrupt/resume determinism, journal recovery.
//!
//! The contract under test (docs/ARCHITECTURE.md, "the distributed
//! campaign plane"): a campaign across any number of worker processes,
//! interrupted and resumed any number of times, produces a report
//! **byte-identical** to a single-process `run_sweep` of the same grid
//! and seed.  These tests run both halves in-process over loopback
//! sockets; `scripts/campaign_smoke.sh` re-proves the same property
//! across real processes with a SIGKILL mid-campaign.

use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use pixelmtj::campaign::{
    journal_header, run_coordinator, run_worker, CampaignOptions,
    CellRecord, Journal, WorkerSummary, DEFAULT_LEASE_TTL,
};
use pixelmtj::config::SweepConfig;
use pixelmtj::device::rng::fmix32;
use pixelmtj::metrics::CampaignMetrics;
use pixelmtj::reports::sweep_report;
use pixelmtj::sweep::{run_sweep, SweepSummary};
use pixelmtj::wire::proto::{
    self, LeaseState, Msg, MsgOutcome, CAMPAIGN_VERSION,
};

/// A small campaign (6 cells) that still exercises multi-lease
/// scheduling at `lease_cells = 2`.
fn quick_cfg() -> SweepConfig {
    SweepConfig {
        grid: "v=0.7,0.8,0.9;k=4,5".to_string(),
        trials: 3,
        threads: 2,
        seed: 7,
        sensor_height: 16,
        sensor_width: 16,
        ..SweepConfig::default()
    }
}

/// Per-test scratch journal path (the parent dir is created by
/// `Journal::open`, removed again by the caller).
fn scratch_journal(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("pixelmtj-campaign-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir.join("campaign.journal")
}

fn campaign_opts(checkpoint: PathBuf) -> CampaignOptions {
    CampaignOptions {
        listen: "127.0.0.1:0".to_string(),
        lease_cells: 2,
        checkpoint,
        lease_ttl: DEFAULT_LEASE_TTL,
    }
}

/// The byte-level report payload — exactly what `sweep_report::save`
/// writes to `reports/sweep.json`.
fn report_bytes(s: &SweepSummary) -> String {
    sweep_report::to_json(s).to_string_pretty()
}

/// Run a coordinator on a thread and `workers` in-process workers
/// against it.  Returns the summary, the `(index)` stream the cell sink
/// observed, and each worker's outcome.
fn run_campaign(
    cfg: SweepConfig,
    opts: CampaignOptions,
    metrics: Option<Arc<CampaignMetrics>>,
    workers: usize,
) -> (SweepSummary, Vec<usize>, Vec<anyhow::Result<WorkerSummary>>) {
    let (tx, rx) = mpsc::channel();
    let coordinator = thread::spawn(move || {
        let mut seen = Vec::new();
        let summary = run_coordinator(
            &cfg,
            &opts,
            metrics.as_deref(),
            |addr| {
                let _ = tx.send(addr);
            },
            |idx, _cell| seen.push(idx),
        )
        .expect("coordinator failed");
        (summary, seen)
    });
    let addr = rx
        .recv_timeout(Duration::from_secs(20))
        .expect("coordinator never reported its listen address")
        .to_string();
    let handles: Vec<_> = (0..workers)
        .map(|_| {
            let addr = addr.clone();
            thread::spawn(move || run_worker(&addr, 1, 0))
        })
        .collect();
    let outcomes: Vec<_> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    let (summary, seen) = coordinator.join().unwrap();
    (summary, seen, outcomes)
}

#[test]
fn two_workers_reassemble_byte_identical_to_run_sweep() {
    let reference = run_sweep(&quick_cfg()).unwrap();
    let journal = scratch_journal("two-workers");

    let (summary, seen, outcomes) = run_campaign(
        quick_cfg(),
        campaign_opts(journal.clone()),
        None,
        2,
    );

    assert_eq!(
        report_bytes(&summary),
        report_bytes(&reference),
        "distributed campaign must serialize byte-identical to run_sweep"
    );
    // Every cell streamed exactly once, and the workers between them
    // completed the whole grid (no reissues happen on a clean run).
    let mut counts = vec![0u32; reference.cells.len()];
    for idx in &seen {
        counts[*idx] += 1;
    }
    assert!(counts.iter().all(|&n| n == 1), "cell deliveries {counts:?}");
    let mut total = 0;
    for outcome in outcomes {
        total += outcome.expect("worker failed").cells_completed;
    }
    assert_eq!(total, reference.cells.len() as u64);

    let _ = std::fs::remove_dir_all(journal.parent().unwrap());
}

#[test]
fn resume_from_interrupted_journal_is_byte_identical() {
    // The uninterrupted reference run: both the expected bytes and the
    // per-cell statistics a killed coordinator would have journaled
    // (cells are pure functions of config + index, so these records are
    // exactly what a real partial campaign persists).
    let cfg = quick_cfg();
    let reference = run_sweep(&cfg).unwrap();
    let n = reference.cells.len();

    // "Kill" at a process-varying cell boundary: any K in 1..n must
    // resume to the same bytes, so the test draws a different one per
    // run without ever passing trivially (K >= 1 cells recovered,
    // K <= n-1 cells still to lease).
    let k = 1 + (fmix32(std::process::id()) as usize) % (n - 1);
    let journal = scratch_journal("resume");
    {
        let header = journal_header(&cfg, n);
        let mut j = Journal::open(&journal, &header).unwrap().journal;
        for (idx, cell) in reference.cells.iter().take(k).enumerate() {
            j.append(&CellRecord {
                index: idx as u64,
                trials: cell.trials,
                elements_per_frame: cell.elements_per_frame,
                ber: cell.ber,
                e10: cell.e10,
                e01: cell.e01,
                agreement: cell.agreement,
                mean_sparsity: cell.mean_sparsity,
                energy_pj_per_frame: cell.energy_pj_per_frame,
            })
            .unwrap();
        }
    }
    // The kill also tore a record mid-append: a plausible length prefix
    // with garbage behind it.  Recovery must drop the tail, keep the K
    // good records, and append cleanly after them.
    {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(&journal)
            .unwrap();
        f.write_all(&[0x45, 0x00, 0x00, 0x00, 0xde, 0xad]).unwrap();
    }

    let metrics = Arc::new(CampaignMetrics::default());
    let (summary, seen, outcomes) = run_campaign(
        cfg,
        campaign_opts(journal.clone()),
        Some(metrics.clone()),
        1,
    );

    assert_eq!(
        report_bytes(&summary),
        report_bytes(&reference),
        "resume with {k} recovered cells must be byte-identical"
    );
    // Recovered cells stream first, in index order; the worker only
    // computed the remainder.
    assert_eq!(&seen[..k], (0..k).collect::<Vec<_>>().as_slice());
    assert_eq!(seen.len(), n);
    assert_eq!(
        outcomes[0].as_ref().unwrap().cells_completed,
        (n - k) as u64
    );
    assert_eq!(metrics.resumes.get(), 1, "resume must be counted");
    assert_eq!(metrics.cells_checkpointed.get(), (n - k) as u64);

    let _ = std::fs::remove_dir_all(journal.parent().unwrap());
}

#[test]
fn fully_journaled_campaign_completes_without_binding_a_listener() {
    let cfg = quick_cfg();
    let reference = run_sweep(&cfg).unwrap();
    let journal = scratch_journal("complete");
    {
        let header = journal_header(&cfg, reference.cells.len());
        let mut j = Journal::open(&journal, &header).unwrap().journal;
        for (idx, cell) in reference.cells.iter().enumerate() {
            j.append(&CellRecord {
                index: idx as u64,
                trials: cell.trials,
                elements_per_frame: cell.elements_per_frame,
                ber: cell.ber,
                e10: cell.e10,
                e01: cell.e01,
                agreement: cell.agreement,
                mean_sparsity: cell.mean_sparsity,
                energy_pj_per_frame: cell.energy_pj_per_frame,
            })
            .unwrap();
        }
    }

    // Nothing remains to lease, so the coordinator must finish from the
    // journal alone — no listener, no workers, no waiting.
    let mut seen = Vec::new();
    let summary = run_coordinator(
        &cfg,
        &campaign_opts(journal.clone()),
        None,
        |addr| panic!("bound a listener at {addr} with zero cells left"),
        |idx, _cell| seen.push(idx),
    )
    .unwrap();

    assert_eq!(report_bytes(&summary), report_bytes(&reference));
    assert_eq!(seen, (0..reference.cells.len()).collect::<Vec<_>>());

    let _ = std::fs::remove_dir_all(journal.parent().unwrap());
}

#[test]
fn dropped_worker_lease_is_reissued_and_resolves_identically() {
    let cfg = quick_cfg();
    let reference = run_sweep(&cfg).unwrap();
    let journal = scratch_journal("reissue");
    let metrics = Arc::new(CampaignMetrics::default());

    let (tx, rx) = mpsc::channel();
    let coordinator = {
        let cfg = cfg.clone();
        let opts = campaign_opts(journal.clone());
        let metrics = metrics.clone();
        thread::spawn(move || {
            run_coordinator(
                &cfg,
                &opts,
                Some(&*metrics),
                |addr| {
                    let _ = tx.send(addr);
                },
                |_idx, _cell| {},
            )
            .expect("coordinator failed")
        })
    };
    let addr = rx
        .recv_timeout(Duration::from_secs(20))
        .expect("coordinator never reported its listen address")
        .to_string();

    // A worker that takes a lease and dies without delivering: raw
    // protocol client, dropped right after the grant.  Its cells must
    // go back on the queue when the socket closes.
    {
        let mut stream = TcpStream::connect(&addr).unwrap();
        proto::write_msg(
            &mut stream,
            &Msg::CampaignHello {
                version: CAMPAIGN_VERSION,
                lease_cells: 2,
            },
        )
        .unwrap();
        match read_one(&mut stream) {
            Msg::CampaignWelcome { trials, grid, .. } => {
                assert_eq!(trials, cfg.trials);
                assert_eq!(grid, cfg.grid);
            }
            other => panic!("expected CAMPAIGN_WELCOME, got {other:?}"),
        }
        proto::write_msg(&mut stream, &Msg::LeaseRequest).unwrap();
        match read_one(&mut stream) {
            Msg::LeaseGrant { state: LeaseState::Granted, count, .. } => {
                assert!(count > 0, "first lease must grant cells");
            }
            other => panic!("expected a granted lease, got {other:?}"),
        }
        // Dropped here: the lease dies with the connection.
    }

    // A real worker then completes the whole grid, reissued cells
    // included.
    let worker = run_worker(&addr, 1, 0).expect("worker failed");
    let summary = coordinator.join().unwrap();

    assert_eq!(
        report_bytes(&summary),
        report_bytes(&reference),
        "a died-and-reissued lease must not perturb the report"
    );
    assert_eq!(worker.cells_completed, reference.cells.len() as u64);
    assert!(
        metrics.leases_expired.get() >= 1,
        "the dropped lease must be reclaimed"
    );

    let _ = std::fs::remove_dir_all(journal.parent().unwrap());
}

fn read_one(stream: &mut TcpStream) -> Msg {
    match proto::read_msg(stream, &|| false) {
        Ok(MsgOutcome::Msg(m)) => m,
        Ok(MsgOutcome::Eof) => panic!("coordinator closed the connection"),
        Ok(MsgOutcome::Stopped) => unreachable!("no stop signal installed"),
        Err(e) => panic!("protocol error: {e}"),
    }
}
