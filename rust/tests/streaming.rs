//! Streaming-layer integration tests: backpressure on the bounded frame
//! queue, clean shutdown with in-flight frames, drain keeping the stream
//! open, stream-vs-oneshot classification parity, and panic containment
//! in the stage threads.  All on the native backend so nothing skips.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use pixelmtj::backend::InferenceBackend;
use pixelmtj::config::{HwConfig, PipelineConfig, SparseCoding};
use pixelmtj::coordinator::{StageHealth, StreamObservers, StreamServer};
use pixelmtj::metrics::PipelineMetrics;
use pixelmtj::sensor::{
    scene::SceneGen, BitPlane, FirstLayerWeights, Frame, PixelArraySim,
};

mod common;
use common::native_pipeline;

/// Run `drain` on a helper thread with a watchdog timeout, so a
/// regression back to the spin-forever behaviour fails the test in
/// seconds instead of hanging the suite.  Returns the drain outcome and
/// hands the server back once the helper has finished with it.
fn drain_with_watchdog(server: StreamServer) -> (Result<usize>, StreamServer) {
    let server = Arc::new(server);
    let (tx, rx) = mpsc::channel();
    {
        let server = server.clone();
        std::thread::spawn(move || {
            let _ = tx.send(server.drain().map(|v| v.len()));
        });
    }
    let outcome = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("drain must return promptly when a stage dies");
    // The helper thread drops its Arc clone just after the send; spin
    // briefly until we hold the last reference.
    let mut server = Arc::try_unwrap(server);
    for _ in 0..500 {
        match server {
            Ok(s) => return (outcome, s),
            Err(arc) => {
                std::thread::sleep(Duration::from_millis(10));
                server = Arc::try_unwrap(arc);
            }
        }
    }
    panic!("drain helper thread did not release the server");
}

fn textured_frames(n: u32) -> Vec<Frame> {
    let gen = SceneGen::new(3, 32, 32);
    (0..n).map(|i| gen.textured(i)).collect()
}

#[test]
fn stream_matches_oneshot_classifications() {
    // Capture noise derives from frame.seq, so the explicit
    // submit/drain path must classify identically to one-shot serve —
    // independent of how frames landed in batches.
    let oneshot = native_pipeline(PipelineConfig::default());
    let a = oneshot.serve(textured_frames(20)).unwrap();

    let streaming = native_pipeline(PipelineConfig::default());
    let server = streaming.stream().unwrap();
    for frame in textured_frames(20) {
        server.submit(frame).unwrap();
    }
    let b = server.drain().unwrap();
    let report = server.shutdown().unwrap();
    assert!(report.results.is_empty(), "drain already took everything");

    assert_eq!(a.results.len(), 20);
    assert_eq!(b.len(), 20);
    for (x, y) in a.results.iter().zip(b.iter()) {
        assert_eq!(x.seq, y.seq);
        assert_eq!(x.label, y.label, "seq {}: labels differ", x.seq);
        assert_eq!(x.logits, y.logits, "seq {}: logits differ", x.seq);
        assert_eq!(x.link_bits, y.link_bits);
    }
}

#[test]
fn try_submit_rejects_at_capacity_then_recovers() {
    // A tiny bounded queue + a producer ~1000× faster than the sensor
    // stage: non-blocking submits must bounce, and the bounced frames
    // must be servable afterwards via blocking submits.
    let cfg = PipelineConfig {
        queue_depth: 1,
        sensor_workers: 1,
        ..PipelineConfig::default()
    };
    let pipeline = native_pipeline(cfg);
    let server = pipeline.stream().unwrap();

    let mut rejected = Vec::new();
    for frame in textured_frames(64) {
        if let Err(frame) = server.try_submit(frame) {
            rejected.push(frame);
        }
    }
    assert!(
        !rejected.is_empty(),
        "a depth-1 queue under a fast producer must reject some frames"
    );
    let metrics = pipeline.metrics();
    assert_eq!(metrics.submit_rejected.get(), rejected.len() as u64);

    for frame in rejected {
        server.submit(frame).unwrap(); // blocking path absorbs the rest
    }
    let results = server.drain().unwrap();
    assert_eq!(results.len(), 64, "no frame may be lost");
    let seqs: Vec<u32> = results.iter().map(|r| r.seq).collect();
    assert_eq!(seqs, (0..64).collect::<Vec<_>>());
    server.shutdown().unwrap();
}

#[test]
fn frames_in_counts_only_ingested_frames_under_rejection() {
    // `frames_in` is the ingestion counter: a rejected try_submit (or a
    // failed blocking submit) must land in `submit_rejected` only, so
    // `frames_in == frames_out + frames_dropped` holds at quiescence.
    let cfg = PipelineConfig {
        queue_depth: 1,
        sensor_workers: 1,
        ..PipelineConfig::default()
    };
    let pipeline = native_pipeline(cfg);
    let server = pipeline.stream().unwrap();
    let mut accepted = 0u64;
    for frame in textured_frames(64) {
        if server.try_submit(frame).is_ok() {
            accepted += 1;
        }
    }
    let results = server.drain().unwrap();
    assert_eq!(results.len() as u64, accepted, "every ingested frame served");
    server.shutdown().unwrap();

    let m = pipeline.metrics();
    assert_eq!(
        m.frames_in.get(),
        accepted,
        "rejected submits must not count as ingested"
    );
    assert_eq!(m.submit_rejected.get(), 64 - accepted);
    assert_eq!(
        m.frames_in.get(),
        m.frames_out.get() + m.frames_dropped.get(),
        "conservation: frames_in == frames_out + frames_dropped"
    );
}

#[test]
fn blocking_submit_bounds_queue_depth() {
    let cfg = PipelineConfig {
        queue_depth: 2,
        sensor_workers: 2,
        ..PipelineConfig::default()
    };
    let pipeline = native_pipeline(cfg);
    let server = pipeline.stream().unwrap();
    for frame in textured_frames(32) {
        server.submit(frame).unwrap();
    }
    let report = server.shutdown().unwrap();
    assert_eq!(report.results.len(), 32);
    let metrics = pipeline.metrics();
    // In-queue frames are capped by the channel bound; the peak counter
    // additionally sees one frame per worker hand and the blocked
    // submitter itself — but never the whole 32-frame workload.
    let peak = metrics.frame_queue_peak.peak();
    assert!(
        peak <= 2 + 2 + 1,
        "backpressure failed: frame queue peaked at {peak}"
    );
    assert_eq!(metrics.frames_dropped.get(), 0);
}

#[test]
fn shutdown_finishes_in_flight_frames() {
    // No drain: shutdown alone must finish everything already submitted.
    let pipeline = native_pipeline(PipelineConfig::default());
    let server = pipeline.stream().unwrap();
    for frame in textured_frames(24) {
        server.submit(frame).unwrap();
    }
    // No in_flight() > 0 assertion here: a slow runner could classify
    // all 24 frames before it runs, flaking the now-enforcing CI gate.
    let report = server.shutdown().unwrap();
    assert_eq!(report.results.len(), 24);
    let seqs: Vec<u32> = report.results.iter().map(|r| r.seq).collect();
    assert_eq!(seqs, (0..24).collect::<Vec<_>>(), "seq-sorted results");
    assert_eq!(report.metrics.frames_out.get(), 24);
}

#[test]
fn drain_keeps_stream_open_for_more_frames() {
    let pipeline = native_pipeline(PipelineConfig::default());
    let server = pipeline.stream().unwrap();
    for frame in textured_frames(8) {
        server.submit(frame).unwrap();
    }
    let first = server.drain().unwrap();
    assert_eq!(first.len(), 8);
    assert_eq!(server.in_flight(), 0);

    let gen = SceneGen::new(3, 32, 32);
    for i in 8..12u32 {
        server.submit(gen.textured(i)).unwrap();
    }
    let second = server.drain().unwrap();
    let seqs: Vec<u32> = second.iter().map(|r| r.seq).collect();
    assert_eq!(seqs, vec![8, 9, 10, 11]);
    server.shutdown().unwrap();
}

#[test]
fn link_verification_is_clean_across_codings() {
    // The release-mode encode/decode parity check in the sensor workers
    // (the promoted debug_assert): a healthy codec must never trip the
    // mismatch counter, for every coding, while results stay identical
    // across codings (the link is lossless by contract).
    let mut labels_by_coding = Vec::new();
    for coding in [SparseCoding::Dense, SparseCoding::Csr, SparseCoding::Rle] {
        let cfg = PipelineConfig {
            sparse_coding: coding,
            ..PipelineConfig::default()
        };
        let pipeline = native_pipeline(cfg);
        let report = pipeline.serve(textured_frames(12)).unwrap();
        assert_eq!(report.results.len(), 12, "{coding:?}");
        assert_eq!(
            report.metrics.link_decode_mismatch.get(),
            0,
            "{coding:?}: link verification tripped on a healthy codec"
        );
        labels_by_coding
            .push(report.results.iter().map(|r| r.label).collect::<Vec<_>>());
    }
    assert_eq!(labels_by_coding[0], labels_by_coding[1]);
    assert_eq!(labels_by_coding[0], labels_by_coding[2]);
}

#[test]
fn worker_panic_fails_drain_and_readyz_promptly() {
    // A frame whose claimed geometry doesn't match its (empty) pixel
    // buffer panics the capture stage via an out-of-bounds slice — a
    // *panic*, not an `Err`.  The stage panic guard must surface it like
    // an error: drain bails out promptly and `/readyz` goes red.
    let cfg = PipelineConfig {
        sensor_workers: 1,
        ..PipelineConfig::default()
    };
    let pipeline = native_pipeline(cfg);
    let health = pipeline.health();
    let server = pipeline.stream().unwrap();
    assert!(health.ready().is_ok(), "stream must start healthy");

    let mut bad = Frame::new(3, 32, 32, 0);
    bad.data.clear();
    server.submit(bad).unwrap();

    let (drained, server) = drain_with_watchdog(server);
    assert!(drained.is_err(), "drain must error on a panicked worker");
    let readyz = health.ready().expect_err("readyz must go red");
    assert!(
        readyz.contains("sensor worker") && readyz.contains("panic"),
        "readyz must name the panicked stage, got: {readyz}"
    );
    let err = server.shutdown().expect_err("shutdown must surface the panic");
    assert!(
        format!("{err:#}").contains("panicked"),
        "shutdown error must mention the panic, got: {err:#}"
    );
}

/// A backend whose batch entry panics — exercises the dispatcher-side
/// panic guard the same way the malformed frame exercises the worker's.
struct PanickingBackend;

impl InferenceBackend for PanickingBackend {
    fn name(&self) -> &'static str {
        "panicking"
    }

    fn act_shape(&self) -> [usize; 3] {
        [32, 15, 15]
    }

    fn num_classes(&self) -> usize {
        10
    }

    fn preload(&self, _batches: &[usize]) -> Result<()> {
        Ok(())
    }

    fn run_frontend(&self, _frame: &Frame) -> Result<BitPlane> {
        unreachable!("streaming never calls run_frontend")
    }

    fn run_backend(&self, _acts: &[f32], _batch: usize) -> Result<Vec<f32>> {
        panic!("injected backend fault")
    }
}

#[test]
fn dispatcher_panic_fails_drain_and_readyz_promptly() {
    let cfg = PipelineConfig {
        sensor_workers: 1,
        ..PipelineConfig::default()
    };
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 1);
    let sim = Arc::new(PixelArraySim::new(hw, weights));
    let backend: Arc<dyn InferenceBackend> = Arc::new(PanickingBackend);
    let metrics = Arc::new(PipelineMetrics::default());
    let health = Arc::new(StageHealth::default());
    let obs = StreamObservers { health: Some(health.clone()), trace: None };
    let server = StreamServer::start_observed(&cfg, sim, backend, metrics, obs).unwrap();
    server.submit(Frame::new(3, 32, 32, 0)).unwrap();

    let (drained, server) = drain_with_watchdog(server);
    assert!(drained.is_err(), "drain must error on a panicked dispatcher");
    let readyz = health.ready().expect_err("readyz must go red");
    assert!(
        readyz.contains("dispatcher") && readyz.contains("panic"),
        "readyz must name the panicked stage, got: {readyz}"
    );
    let err = server.shutdown().expect_err("shutdown must surface the panic");
    assert!(
        format!("{err:#}").contains("dispatcher panicked"),
        "shutdown error must blame the dispatcher, got: {err:#}"
    );
}

#[test]
fn stream_rejects_batch_sizes_without_single_frame_fallback() {
    let cfg = PipelineConfig {
        batch_sizes: vec![8],
        ..PipelineConfig::default()
    };
    let pipeline = native_pipeline(cfg);
    let err = match pipeline.stream() {
        Ok(_) => panic!("must refuse batch_sizes without the size-1 fallback"),
        Err(e) => e,
    };
    assert!(format!("{err}").contains("batch_sizes"));
}
