//! Idle-session soak: the reactor claim made concrete.  A
//! thread-per-session server pays one OS thread per connected client;
//! the readiness reactor pays one thread total, with per-session stage
//! threads appearing only once a session actually ships a frame.  This
//! test parks 64 negotiated-but-idle sessions on a live server and
//! asserts the process thread count does not move.
//!
//! Lives in its own integration binary on purpose: `/proc/self/task`
//! counts every thread in the process, so sharing a binary with the
//! other wire tests (whose pipelines spawn stage workers concurrently)
//! would make the baseline racy.

#![cfg(target_os = "linux")]

use std::time::{Duration, Instant};

use pixelmtj::config::{HwConfig, WireCoding};
use pixelmtj::system::System;
use pixelmtj::wire::WireClient;

const IDLE_SESSIONS: usize = 64;

/// Threads alive in this process right now.
fn thread_count() -> usize {
    std::fs::read_dir("/proc/self/task")
        .expect("/proc/self/task readable on linux")
        .count()
}

#[test]
fn idle_sessions_hold_a_constant_thread_count() {
    let mut sys = System::builder()
        .artifacts_dir("/nonexistent")
        .workers(2)
        .listen("127.0.0.1:0")
        .max_sessions(IDLE_SESSIONS as u64 + 8)
        .build();
    let mut svc = sys.serve_wire().unwrap();
    let addr = svc.server.local_addr().to_string();
    let channels = HwConfig::default().network.in_channels;
    let (height, width) = (
        sys.spec().pipeline.sensor_height,
        sys.spec().pipeline.sensor_width,
    );

    // Negotiate one session first, then take the baseline: the reactor
    // thread is already up, so every later connect must be thread-free.
    let connect = || {
        WireClient::connect(&addr, WireCoding::Csr, channels, height, width)
            .expect("idle session negotiates")
    };
    let mut clients = vec![connect()];
    let baseline = thread_count();
    while clients.len() < IDLE_SESSIONS {
        clients.push(connect());
    }
    assert_eq!(
        svc.metrics.sessions_active(),
        IDLE_SESSIONS as u64,
        "every connect returned with HELLO_ACK, so every slot is held"
    );

    // Let the reactor tick a few times with all sessions parked, then
    // measure: no per-session threads may have appeared.
    std::thread::sleep(Duration::from_millis(200));
    assert_eq!(
        thread_count(),
        baseline,
        "{IDLE_SESSIONS} idle sessions must not grow the thread count"
    );

    // Hanging up without GOODBYE is a silent close: slots drain, no
    // protocol errors are counted, and the reactor thread survives.
    drop(clients);
    let deadline = Instant::now() + Duration::from_secs(30);
    while svc.metrics.sessions_active() != 0 {
        assert!(Instant::now() < deadline, "sessions never released slots");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(svc.metrics.sessions_total.get(), IDLE_SESSIONS as u64);
    assert_eq!(svc.metrics.frames_received.get(), 0);
    assert_eq!(thread_count(), baseline, "slot release spawned no threads");
    svc.server.shutdown();
}
