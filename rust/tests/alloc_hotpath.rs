//! Steady-state allocation audit for the frame hot path.
//!
//! The tentpole contract of the zero-allocation refactor: once every
//! reusable buffer has warmed up, one frame's trip through
//! capture → link encode → link decode → packed inference performs
//! **zero** heap allocations.  A counting `#[global_allocator]` wrapper
//! proves it — the counter only runs while this thread's tracking flag
//! is up, so harness noise on other threads cannot flake the assert.
//!
//! Scope: this pins the per-frame stage loop the stream workers and the
//! dispatcher run (with one inference worker).  The user-facing
//! `Classification` payload (its per-frame logits `Vec`) and the
//! batcher's batch `Vec` are intentional allocations outside this path
//! and are documented in rust/README.md.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

use pixelmtj::backend::{InferenceBackend, NativeBackend};
use pixelmtj::config::{HwConfig, SparseCoding};
use pixelmtj::coordinator::sparse::{decode_into, encode_into, Encoded};
use pixelmtj::sensor::{
    scene::SceneGen, BitPlane, CaptureMode, FirstLayerWeights, PixelArraySim,
};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Only count while the measuring thread holds this flag up —
    /// allocations from the libtest harness or other threads are noise.
    static TRACK: Cell<bool> = const { Cell::new(false) };
}

struct CountingAlloc;

impl CountingAlloc {
    fn count() {
        // `try_with` so allocations during TLS teardown can't panic.
        if TRACK.try_with(Cell::get).unwrap_or(false) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::count();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::count();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::count();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_frame_loop_allocates_nothing() {
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 1);
    let sim = PixelArraySim::new(hw.clone(), weights.clone());
    let backend = NativeBackend::new(hw, weights, 32, 32, 1);
    let gen = SceneGen::new(3, 32, 32);
    let frames: Vec<_> = (0..4u32).map(|i| gen.textured(i)).collect();

    for coding in [SparseCoding::Dense, SparseCoding::Csr, SparseCoding::Rle] {
        // The stage-owned reusable buffers, exactly as the stream worker
        // and dispatcher hold them.
        let mut cap = BitPlane::empty();
        let mut enc = Encoded::empty(coding);
        let mut dec = BitPlane::empty();
        let mut logits: Vec<f32> = Vec::new();

        // Warm up: grow every buffer (including the thread-local capture
        // and inference scratch) to this geometry's steady-state size.
        for _ in 0..2 {
            for frame in &frames {
                sim.capture_reuse(frame, CaptureMode::Ideal, &mut cap);
                encode_into(&cap, coding, &mut enc);
                decode_into(&enc, &mut dec).unwrap();
                backend
                    .run_backend_packed_into(dec.words(), 1, &mut logits)
                    .unwrap();
            }
        }

        // Measure: the same per-frame loop must not touch the heap.
        TRACK.with(|t| t.set(true));
        for frame in &frames {
            sim.capture_reuse(frame, CaptureMode::Ideal, &mut cap);
            encode_into(&cap, coding, &mut enc);
            decode_into(&enc, &mut dec).unwrap();
            backend
                .run_backend_packed_into(dec.words(), 1, &mut logits)
                .unwrap();
        }
        TRACK.with(|t| t.set(false));
        let allocs = ALLOCS.swap(0, Ordering::SeqCst);
        assert_eq!(
            allocs, 0,
            "{coding:?}: steady-state frame loop hit the allocator \
             {allocs} times"
        );
        assert_eq!(dec.words(), cap.words(), "{coding:?}: link must stay lossless");
        assert_eq!(logits.len(), backend.num_classes());
    }
}
