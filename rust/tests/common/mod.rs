//! Shared scaffolding for the native-backend integration tests
//! (`tests/integration.rs`, `tests/streaming.rs`).

use pixelmtj::config::PipelineConfig;
use pixelmtj::coordinator::Pipeline;

/// A pipeline over the native backend with deterministic synthetic
/// weights — no artifacts needed, so these tests never skip.
pub fn native_pipeline(cfg: PipelineConfig) -> Pipeline {
    Pipeline::synthetic_native(cfg).unwrap()
}
