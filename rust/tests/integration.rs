//! Integration tests: the serving pipeline end to end over the pluggable
//! backend, plus cross-module flows (sensor → codec → energy accounting).
//! The pipeline tests run on the native backend so they never skip; the
//! AOT-artifact tests live in the `pjrt` module (feature-gated) and skip
//! when artifacts are absent.

use pixelmtj::config::{HwConfig, PipelineConfig, SparseCoding};
use pixelmtj::coordinator::sparse;
use pixelmtj::energy::{self, Geometry};
use pixelmtj::sensor::{
    scene::SceneGen, CaptureMode, FirstLayerWeights, PixelArraySim,
};

mod common;
use common::native_pipeline;

#[test]
fn pipeline_serves_all_frames_in_order() {
    let pipeline = native_pipeline(PipelineConfig::default());
    let gen = SceneGen::new(3, 32, 32);
    let frames: Vec<_> = (0..40u32).map(|i| gen.textured(i)).collect();
    let report = pipeline.serve(frames).unwrap();
    assert_eq!(report.results.len(), 40);
    let seqs: Vec<u32> = report.results.iter().map(|r| r.seq).collect();
    assert_eq!(seqs, (0..40).collect::<Vec<_>>(), "results must be ordered");
    assert_eq!(report.metrics.frames_out.get(), 40);
    assert_eq!(report.metrics.frames_dropped.get(), 0);
    assert!(report.fps > 0.0);
}

#[test]
fn pipeline_is_deterministic_across_runs() {
    let p1 = native_pipeline(PipelineConfig::default());
    let p2 = native_pipeline(PipelineConfig::default());
    let gen = SceneGen::new(3, 32, 32);
    let frames: Vec<_> = (0..16u32).map(|i| gen.textured(i)).collect();
    let a = p1.serve(frames.clone()).unwrap();
    let b = p2.serve(frames).unwrap();
    for (x, y) in a.results.iter().zip(b.results.iter()) {
        assert_eq!(x.seq, y.seq);
        assert_eq!(x.label, y.label, "seq {}: labels differ", x.seq);
        assert_eq!(x.link_bits, y.link_bits);
    }
}

#[test]
fn pipeline_batches_fill_under_load() {
    let cfg = PipelineConfig {
        batch_timeout_us: 50_000, // generous: let batches fill
        ..PipelineConfig::default()
    };
    let pipeline = native_pipeline(cfg);
    let gen = SceneGen::new(3, 32, 32);
    let frames: Vec<_> = (0..64u32).map(|i| gen.textured(i)).collect();
    let report = pipeline.serve(frames).unwrap();
    assert!(
        report.metrics.mean_batch_occupancy() > 2.0,
        "expected batched dispatch, got mean occupancy {}",
        report.metrics.mean_batch_occupancy()
    );
}

#[test]
fn codecs_agree_and_bits_feed_energy_model() {
    // Sensor → each codec → identical decode → energy accounting.
    let hw = HwConfig::default();
    let sim = PixelArraySim::new(
        hw.clone(),
        FirstLayerWeights::synthetic(32, 3, 3, 3),
    );
    let frame = SceneGen::new(3, 32, 32).textured(11);
    let (map, stats) = sim.capture(&frame, CaptureMode::CalibratedMtj);
    let mut payloads = Vec::new();
    for coding in [SparseCoding::Dense, SparseCoding::Csr, SparseCoding::Rle] {
        let enc = sparse::encode(&map, coding);
        let dec = sparse::decode(&enc).unwrap();
        assert_eq!(dec.words(), map.words(), "{coding:?} roundtrip");
        payloads.push(enc.payload_bits);
    }
    // Energy model consumes the measured bits.
    let geom = Geometry::from_cfg(&hw, 32, 32);
    let fe = energy::frontend_ours(&geom, &stats).total_pj();
    assert!(fe > 0.0);
    let comm = energy::comm_energy_pj(payloads[2]);
    assert!(comm > 0.0 && comm < energy::comm_energy_pj(payloads[0]) * 2.0);
}

/// Tests that execute the AOT artifacts through the PJRT backend; these
/// skip when artifacts have not been built.
#[cfg(feature = "pjrt")]
mod pjrt {
    use std::path::PathBuf;

    use pixelmtj::backend::{InferenceBackend, PjrtBackend};
    use pixelmtj::config::HwConfig;
    use pixelmtj::reports::{evalset_accuracy, EvalSet};
    use pixelmtj::sensor::{
        scene::SceneGen, CaptureMode, FirstLayerWeights, PixelArraySim,
    };

    fn artifacts() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts().join("meta.json").exists()
    }

    fn setup() -> (PjrtBackend, PixelArraySim, EvalSet) {
        let hw = HwConfig::load_or_default(&artifacts());
        let weights =
            FirstLayerWeights::from_golden(artifacts().join("golden.json"))
                .unwrap();
        let sim = PixelArraySim::new(hw, weights);
        let backend = PjrtBackend::new(&artifacts()).unwrap();
        let eval = EvalSet::load(&artifacts().join("evalset.json")).unwrap();
        (backend, sim, eval)
    }

    #[test]
    fn evalset_accuracy_beats_chance_and_mtj_noise_is_mild() {
        if !have_artifacts() {
            return;
        }
        let (backend, sim, eval) = setup();
        let (acc_ideal, sparsity) =
            evalset_accuracy(&backend, &sim, &eval, CaptureMode::Ideal, None)
                .unwrap();
        let (acc_mtj, _) = evalset_accuracy(
            &backend,
            &sim,
            &eval,
            CaptureMode::CalibratedMtj,
            None,
        )
        .unwrap();
        assert!(
            acc_ideal > 0.5,
            "trained model should beat chance: {acc_ideal}"
        );
        assert!(
            acc_ideal - acc_mtj < 0.08,
            "multi-MTJ noise cost too high: {acc_ideal} → {acc_mtj}"
        );
        assert!(
            sparsity > 0.5,
            "trained activations should be sparse: {sparsity}"
        );
    }

    #[test]
    fn fig8_error_asymmetry_holds() {
        if !have_artifacts() {
            return;
        }
        // Paper Fig. 8: 0→1 errors (spurious activations in a sparse map)
        // degrade accuracy much faster than 1→0 errors.
        let (backend, sim, eval) = setup();
        let (acc_10, _) = evalset_accuracy(
            &backend,
            &sim,
            &eval,
            CaptureMode::Ideal,
            Some((0.10, 0.0)),
        )
        .unwrap();
        let (acc_01, _) = evalset_accuracy(
            &backend,
            &sim,
            &eval,
            CaptureMode::Ideal,
            Some((0.0, 0.10)),
        )
        .unwrap();
        assert!(
            acc_10 > acc_01 + 0.1,
            "expected 1→0 tolerance ≫ 0→1: {acc_10} vs {acc_01}"
        );
    }

    #[test]
    fn frontend_artifact_matches_sensor_sim_on_fresh_scenes() {
        if !have_artifacts() {
            return;
        }
        // Beyond the golden vector: arbitrary scenes must agree too.
        let (backend, sim, _) = setup();
        let gen = SceneGen::new(3, 32, 32);
        for seq in [3u32, 17, 99] {
            let frame = gen.textured(seq);
            let (map, _) = sim.capture(&frame, CaptureMode::Ideal);
            let aot = backend.run_frontend(&frame).unwrap();
            let (f10, f01) = map.flips(&aot);
            let agree = 1.0 - (f10 + f01) as f64 / aot.len() as f64;
            assert!(
                agree >= 0.999,
                "seq {seq}: sensor sim vs AOT agreement {agree}"
            );
        }
    }
}
