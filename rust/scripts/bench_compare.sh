#!/usr/bin/env bash
# Warn-only bench regression gate: compare the fresh BENCH_*.json
# throughput numbers (written by `cargo bench` into rust/) against the
# committed baselines in benches/baseline/.  Never fails the build —
# shared CI runners make timings too noisy for a hard gate — but a perf
# cliff shows up as a ::warning annotation on the PR.
#
# Baselines marked `"provisional": true` were estimated without a local
# toolchain; the warning text says so.  Bless real numbers by replacing
# the baseline file with a CI artifact from a healthy run.
set -u
cd "$(dirname "$0")/.."

python3 - <<'PY'
import json

TOLERANCE = 0.4  # warn when fresh throughput drops below 40% of baseline


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def warn(msg):
    print(f"::warning::bench-compare: {msg}")


def compare(name, fresh_val, base_val, provisional):
    if not isinstance(fresh_val, (int, float)):
        return 0
    if not isinstance(base_val, (int, float)) or base_val <= 0:
        return 0
    if fresh_val < TOLERANCE * base_val:
        tag = " (baseline is provisional)" if provisional else ""
        warn(
            f"{name}: {fresh_val:.1f} vs baseline {base_val:.1f} "
            f"— below {TOLERANCE:.0%} of baseline{tag}"
        )
    return 1


checked = 0

base = load("benches/baseline/BENCH_stream.json")
fresh = load("BENCH_stream.json")
if base and fresh:
    prov = bool(base.get("provisional"))
    for key in ("single_worker_fps", "multi_worker_fps"):
        if key in base and key in fresh:
            checked += compare(f"stream.{key}", fresh[key], base[key], prov)
elif base:
    warn("BENCH_stream.json missing — stream bench produced no output")

base = load("benches/baseline/BENCH_pack.json")
fresh = load("BENCH_pack.json")
if base and fresh:
    prov = bool(base.get("provisional"))
    by_name = {
        g.get("geometry"): g
        for g in base.get("geometries", [])
        if isinstance(g, dict)
    }
    for g in fresh.get("geometries", []):
        if not isinstance(g, dict):
            continue
        bg = by_name.get(g.get("geometry"))
        if bg and "e2e_packed_fps" in bg and "e2e_packed_fps" in g:
            checked += compare(
                f"pack.{g['geometry']}.e2e_packed_fps",
                g["e2e_packed_fps"],
                bg["e2e_packed_fps"],
                prov,
            )
elif base:
    warn("BENCH_pack.json missing — pack bench produced no output")

base = load("benches/baseline/BENCH_wire.json")
fresh = load("BENCH_wire.json")
if base and fresh:
    prov = bool(base.get("provisional"))
    by_tier = {
        (r.get("sessions"), r.get("batch_frames")): r
        for r in base.get("runs", [])
        if isinstance(r, dict)
    }
    for r in fresh.get("runs", []):
        if not isinstance(r, dict):
            continue
        br = by_tier.get((r.get("sessions"), r.get("batch_frames")))
        if br and "fps" in br and "fps" in r:
            checked += compare(
                f"wire.s{r['sessions']}.b{r['batch_frames']}.fps",
                r["fps"],
                br["fps"],
                prov,
            )
    # Bandwidth is deterministic (no timing noise), so drift here is a
    # protocol change, not runner jitter — still warn-only by policy.
    for key in ("v1_bytes_per_frame", "batched_bytes_per_frame"):
        if key in base and key in fresh and fresh[key] > base[key] * 1.05:
            tag = " (baseline is provisional)" if prov else ""
            warn(
                f"wire.{key}: {fresh[key]:.1f} B vs baseline "
                f"{base[key]:.1f} B — bandwidth regressed{tag}"
            )
elif base:
    warn("BENCH_wire.json missing — wire bench produced no output")

base = load("benches/baseline/BENCH_campaign.json")
fresh = load("BENCH_campaign.json")
if base and fresh:
    prov = bool(base.get("provisional"))
    by_workers = {
        r.get("workers"): r
        for r in base.get("runs", [])
        if isinstance(r, dict)
    }
    for r in fresh.get("runs", []):
        if not isinstance(r, dict):
            continue
        br = by_workers.get(r.get("workers"))
        if br and "cells_per_sec" in br and "cells_per_sec" in r:
            checked += compare(
                f"campaign.w{r['workers']}.cells_per_sec",
                r["cells_per_sec"],
                br["cells_per_sec"],
                prov,
            )
elif base:
    warn("BENCH_campaign.json missing — campaign bench produced no output")

print(f"bench-compare: {checked} throughput keys checked (warn-only)")
PY

exit 0
