#!/usr/bin/env bash
# End-to-end telemetry smoke (CI runs this via `make metrics-smoke`):
# serve --stream with the exposition server on an ephemeral port, curl
# /healthz + /readyz + /metrics while frames flow, check the required
# metric families, then verify the per-frame trace-log JSONL.
#
# The bursty workload paces the stream (~250 bursts x 20 ms idle), so the
# run lasts a few seconds on any machine — long enough to scrape mid-run
# without depending on backend throughput.
set -euo pipefail
cd "$(dirname "$0")/.."
. scripts/lib.sh

TRACE=trace_smoke.jsonl
LOG=$(mktemp)
rm -f "$TRACE"

cargo build --release
cargo run --release -- serve --stream --workload bursty \
  --frames 2000 --burst-len 8 --burst-gap-us 20000 --workers 2 \
  --metrics-addr 127.0.0.1:0 --trace-log "$TRACE" >"$LOG" 2>&1 &
PID=$!
trap 'kill $PID 2>/dev/null || true' EXIT

# The CLI prints the bound address (port 0 → ephemeral) before serving.
LINE=$(await_line '^telemetry: http://' "$LOG" "$PID")
ADDR=${LINE#telemetry: http://}
ADDR=${ADDR%%/*}
echo "scraping http://$ADDR mid-run"

curl -sf "http://$ADDR/healthz" | grep -q '^ok$'
curl -sf "http://$ADDR/readyz" | grep -q '^ready$'
METRICS=$(curl -sf "http://$ADDR/metrics")

for fam in pixelmtj_up pixelmtj_frames_in_total pixelmtj_batches_total \
  pixelmtj_link_bits_total pixelmtj_stage_latency_us \
  pixelmtj_frame_queue_peak; do
  if ! echo "$METRICS" | grep -q "$fam"; then
    echo "FAIL: /metrics is missing family $fam" >&2
    echo "$METRICS" >&2
    exit 1
  fi
done
FAMS=$(echo "$METRICS" | grep -c '^# TYPE')
if [ "$FAMS" -lt 5 ]; then
  echo "FAIL: only $FAMS metric families exposed" >&2
  exit 1
fi

wait "$PID"
trap - EXIT

if ! [ -s "$TRACE" ]; then
  echo "FAIL: trace log $TRACE is empty" >&2
  exit 1
fi
head -n 1 "$TRACE" | grep -q '"trace_id"'
SPANS=$(wc -l <"$TRACE")
echo "metrics smoke OK: $FAMS families, $SPANS trace spans"
