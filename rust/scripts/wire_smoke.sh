#!/usr/bin/env bash
# End-to-end wire-protocol smoke (CI runs this via `make wire-smoke`):
# `serve --stream --listen` on an ephemeral port, driven by the
# `pixelmtj push` wire client in two sessions with a hostile non-PXMJ
# probe in between, pinning the pixelmtj_wire_* metric families against
# the exact frame arithmetic.  The full transcript lands in
# wire_smoke_transcript.txt (uploaded as a CI artifact on every run).
set -euo pipefail
cd "$(dirname "$0")/.."
. scripts/lib.sh

TRANSCRIPT=wire_smoke_transcript.txt
exec > >(tee "$TRANSCRIPT") 2>&1

LOG=$(mktemp)
PROBE=$(mktemp)
PUSH=$(mktemp)

cargo build --release
BIN=target/release/pixelmtj

# Ingest budget 48: the server exits on its own once 48 frames arrived
# and the last session drained — no kill/timeout choreography needed.
"$BIN" serve --stream --listen 127.0.0.1:0 --metrics-addr 127.0.0.1:0 \
  --frames 48 --workers 2 >"$LOG" 2>&1 &
PID=$!
trap 'kill $PID 2>/dev/null || true' EXIT

LINE=$(await_line '^wire: listening on ' "$LOG" "$PID")
ADDR=${LINE#wire: listening on }
LINE=$(await_line '^telemetry: http://' "$LOG" "$PID")
MADDR=${LINE#telemetry: http://}
MADDR=${MADDR%%/*}
echo "server up: wire=$ADDR metrics=$MADDR"

# Session 1: 24 bursty frames, binarized client-side and shipped as CSR
# (the paper's "ship binary activations, not pixels" link over TCP).
"$BIN" push --connect "$ADDR" --wire-coding csr --frames 24 \
  --workload bursty --burst-len 8 --burst-gap-us 5000 | tee "$PUSH"
grep -q '^pushed 24 frames, received 24 results' "$PUSH"

# Hostile probe: curl speaks HTTP at the wire port, and "GET / HTT" is
# not a PXMJ envelope — the server must answer the typed ERROR from
# docs/PROTOCOL.md and close.  (--http0.9 lets curl keep the raw reply;
# if this curl lacks it, the metrics assertion below still gates.)
curl -s --max-time 5 --http0.9 -o "$PROBE" "http://$ADDR/" || true
if grep -aq 'PXMJ' "$PROBE"; then
  echo "probe: typed ERROR envelope received"
else
  echo "probe: raw reply not captured; the bad_magic metric gates it"
fi

# Mid-run scrape: exact arithmetic.  RESULTs flush before the server's
# closing GOODBYE, so after push exits these counters are settled.
METRICS=$(curl -sf "http://$MADDR/metrics")
for want in \
  'pixelmtj_wire_sessions_total 1' \
  'pixelmtj_wire_frames_received_total 24' \
  'pixelmtj_wire_results_sent_total 24' \
  'pixelmtj_wire_session_rejections_total 0' \
  'pixelmtj_wire_protocol_errors_total{code="bad_magic"} 1' \
  'pixelmtj_wire_protocol_errors_total{code="bad_frame"} 0'; do
  if ! echo "$METRICS" | grep -qF -x -- "$want"; then
    echo "FAIL: /metrics is missing exact sample: $want" >&2
    echo "$METRICS" | grep pixelmtj_wire >&2 || echo "$METRICS" >&2
    exit 1
  fi
done
curl -sf "http://$MADDR/readyz" | grep -q '^ready$'
echo "mid-run scrape OK"

# Session 2 fills the ingest budget (dense coding for coverage) over a
# protocol-v2 session with 8 frames per FRAME_BATCH envelope.
"$BIN" push --connect "$ADDR" --wire-coding dense --frames 24 \
  --batch-frames 8 | tee "$PUSH"
grep -q '^push: protocol v2, 8 frames/envelope' "$PUSH"
grep -q '^pushed 24 frames, received 24 results' "$PUSH"

wait "$PID"
trap - EXIT
cat "$LOG"
grep -q '48 frames over 2 sessions' "$LOG"
grep -q '48 results, 1 protocol errors' "$LOG"
rm -f "$LOG" "$PROBE" "$PUSH"
echo "wire smoke OK: 48 frames, 2 sessions, 1 typed protocol error"
