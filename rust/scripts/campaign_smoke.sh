#!/usr/bin/env bash
# Distributed-campaign smoke (CI runs this via `make campaign-smoke`):
# a coordinator and two worker processes over loopback, one worker
# SIGKILLed mid-campaign, then the coordinator itself SIGKILLed and
# resumed from its checkpoint journal — and the reassembled report must
# be byte-identical to a single-process `sweep` of the same grid/seed.
# The full transcript lands in campaign_smoke_transcript.txt (uploaded
# as a CI artifact on every run).
set -euo pipefail
cd "$(dirname "$0")/.."
. scripts/lib.sh

TRANSCRIPT=campaign_smoke_transcript.txt
exec > >(tee "$TRANSCRIPT") 2>&1

GRID='v=0.7,0.75,0.8,0.85,0.9,0.95;k=4,5;sigma=0,0.02'   # 24 cells
TRIALS=10
SEED=11

WORK=$(mktemp -d)
COORD= W1= W2= W3=
cleanup() {
  for p in $COORD $W1 $W2 $W3; do kill -9 "$p" 2>/dev/null || true; done
  rm -rf "$WORK"
}
trap cleanup EXIT

# await_rows N LOG PID — poll until LOG holds at least N result-table
# rows (cells stream live, so row count tracks durable progress).
# Returns nonzero once PID is gone; the caller decides if that matters.
await_rows() {
  local n=$1 log=$2 pid=$3 _i
  for _i in $(seq 1 300); do
    if [ "$(grep -Ec '^ *[0-9]+ .*\|' "$log" 2>/dev/null || true)" -ge "$n" ]; then
      return 0
    fi
    kill -0 "$pid" 2>/dev/null || return 1
    sleep 0.1
  done
  return 1
}

cargo build --release
BIN=target/release/pixelmtj

echo "== reference: single-process sweep =="
"$BIN" sweep --grid "$GRID" --trials "$TRIALS" --seed "$SEED" \
  --threads 2 --out "$WORK/ref" >"$WORK/ref.log" 2>&1
test -f "$WORK/ref/sweep.json"
echo "reference report written"

echo "== round 1: coordinator + 2 workers, SIGKILL both mid-campaign =="
"$BIN" campaign --coordinate 127.0.0.1:0 --grid "$GRID" \
  --trials "$TRIALS" --seed "$SEED" --lease-cells 1 \
  --checkpoint "$WORK/campaign.journal" --out "$WORK/camp" \
  >"$WORK/coord1.log" 2>&1 &
COORD=$!
LINE=$(await_line '^campaign: listening on ' "$WORK/coord1.log" "$COORD")
ADDR=${LINE#campaign: listening on }
echo "coordinator up at $ADDR"

"$BIN" work --join "$ADDR" --threads 1 --lease-cells 1 \
  >"$WORK/w1.log" 2>&1 &
W1=$!
"$BIN" work --join "$ADDR" --threads 1 --lease-cells 1 \
  >"$WORK/w2.log" 2>&1 &
W2=$!

# Let a couple of cells checkpoint, then murder one worker outright —
# its outstanding lease must be reissued, not lost.
if await_rows 2 "$WORK/coord1.log" "$COORD"; then
  kill -9 "$W1" 2>/dev/null || true
  echo "worker 1 SIGKILLed mid-campaign"
else
  echo "campaign finished before the worker kill landed (fast machine)"
fi

# More progress, then murder the coordinator itself mid-campaign.  The
# journal (fsync'd per cell) is all that survives.
if await_rows 4 "$WORK/coord1.log" "$COORD"; then
  kill -9 "$COORD" 2>/dev/null || true
  echo "coordinator SIGKILLed mid-campaign"
else
  echo "campaign finished before the coordinator kill landed"
fi
wait "$COORD" 2>/dev/null || true
wait "$W1" 2>/dev/null || true
wait "$W2" 2>/dev/null || true
COORD= W1= W2=

echo "== round 2: resume from the checkpoint journal =="
"$BIN" campaign --coordinate 127.0.0.1:0 --grid "$GRID" \
  --trials "$TRIALS" --seed "$SEED" --lease-cells 1 \
  --checkpoint "$WORK/campaign.journal" --out "$WORK/camp" \
  >"$WORK/coord2.log" 2>&1 &
COORD=$!
# A listener only binds when cells remain; a fully journaled round 1
# (possible on a very fast machine) resumes straight to the report.
if LINE=$(await_line '^campaign: listening on ' "$WORK/coord2.log" "$COORD" 2>/dev/null); then
  ADDR=${LINE#campaign: listening on }
  echo "resumed coordinator up at $ADDR"
  "$BIN" work --join "$ADDR" --threads 1 --lease-cells 1 \
    >"$WORK/w3.log" 2>&1 &
  W3=$!
  wait "$W3"
  W3=
  echo "resume worker finished"
else
  echo "journal already complete — coordinator resumed without a listener"
fi
wait "$COORD"
COORD=

echo "== coordinator round 2 transcript =="
cat "$WORK/coord2.log"

# Every cell appears exactly once in the resumed run's live table
# (recovered cells first, then the remainder as it completes).
ROWS=$(grep -Ec '^ *[0-9]+ .*\|' "$WORK/coord2.log")
if [ "$ROWS" -ne 24 ]; then
  echo "FAIL: resumed coordinator streamed $ROWS rows, want 24" >&2
  exit 1
fi
grep -Eq "^24 cells × $TRIALS trials" "$WORK/coord2.log"

# The contract: byte-identical to the single-process sweep.
if ! cmp "$WORK/ref/sweep.json" "$WORK/camp/sweep.json"; then
  echo "FAIL: campaign report differs from single-process sweep" >&2
  diff "$WORK/ref/sweep.json" "$WORK/camp/sweep.json" >&2 || true
  exit 1
fi
echo "campaign smoke OK: kill/resume report byte-identical to sweep"
