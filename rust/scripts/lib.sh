# Shared helpers for the smoke scripts (sourced, not executed).

# await_line REGEX LOG [PID]
#
# Poll LOG until a line matches REGEX (grep -E) and echo the first
# match.  Fails fast if PID exits before the line appears, and after
# ~30 s either way — dumping LOG to stderr so CI failures carry the
# evidence.  Replaces ad-hoc sed retry loops: the one pattern every
# smoke needs is "wait for the server to print its bound address".
await_line() {
  local regex=$1 log=$2 pid=${3:-}
  local _i line
  for _i in $(seq 1 300); do
    line=$(grep -E -m1 -- "$regex" "$log" 2>/dev/null || true)
    if [ -n "$line" ]; then
      printf '%s\n' "$line"
      return 0
    fi
    if [ -n "$pid" ] && ! kill -0 "$pid" 2>/dev/null; then
      echo "FAIL: process $pid exited before printing /$regex/" >&2
      cat "$log" >&2
      return 1
    fi
    sleep 0.1
  done
  echo "FAIL: timed out waiting for /$regex/ in $log" >&2
  cat "$log" >&2
  return 1
}
