//! Backend benches: the native XNOR-popcount engine vs its dense f32
//! reference — the classifier hot path behind the serving pipeline.
//! Emits `BENCH_backend.json` (frames/sec for both paths + speedup) so
//! the perf trajectory is machine-diffable across PRs.

use pixelmtj::backend::{
    active_simd, InferScratch, InferenceBackend, NativeBackend, NativePath,
};
use pixelmtj::config::HwConfig;
use pixelmtj::sensor::{scene::SceneGen, words_for, FirstLayerWeights};
use pixelmtj::util::bench::{bb, Bencher};
use pixelmtj::util::json::Value;

fn main() {
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 1);
    let packed = NativeBackend::new(hw.clone(), weights.clone(), 32, 32, 4);
    let dense = NativeBackend::new(hw, weights, 32, 32, 4)
        .with_path(NativePath::DenseRef);
    println!("model: {}\n", packed.arch());

    // Real activation maps from the in-pixel frontend (≈80 % sparse).
    let gen = SceneGen::new(3, 32, 32);
    let act = packed.run_frontend(&gen.textured(5)).unwrap().to_f32();
    let elems = packed.act_elems();
    let mut batch8 = Vec::with_capacity(8 * elems);
    for i in 0..8u32 {
        batch8.extend(packed.run_frontend(&gen.textured(i)).unwrap().to_f32());
    }

    // Packed-domain batch (BitPlane words, no f32 widening) — the exact
    // representation the stream dispatcher feeds, for the kernel arms.
    let model = packed.model();
    let wpf = words_for(elems);
    let nc = model.num_classes();
    let mut batch8_words = Vec::with_capacity(8 * wpf);
    for i in 0..8u32 {
        let map = packed.run_frontend(&gen.textured(i)).unwrap();
        batch8_words.extend_from_slice(map.words());
    }

    let mut b = Bencher::new("backend");
    let s_packed1 = b
        .bench("native_xnor_b1", || {
            bb(packed.run_backend(bb(&act), 1).unwrap());
        })
        .clone();
    let s_dense1 = b
        .bench("dense_reference_b1", || {
            bb(dense.run_backend(bb(&act), 1).unwrap());
        })
        .clone();
    let s_packed8 = b
        .bench("native_xnor_b8", || {
            bb(packed.run_backend(bb(&batch8), 8).unwrap());
        })
        .clone();
    let s_dense8 = b
        .bench("dense_reference_b8", || {
            bb(dense.run_backend(bb(&batch8), 8).unwrap());
        })
        .clone();

    // Kernel-level arms: the runtime-dispatched SIMD XNOR-popcount vs
    // the forced-scalar loop, both over the zero-allocation batched
    // entry (shared scratch, caller-owned logits).
    let mut scratch = InferScratch::default();
    let mut logits = vec![0.0f32; 8 * nc];
    let s_simd8 = b
        .bench("packed_words_simd_b8", || {
            model.infer_batch_words(bb(&batch8_words), 8, &mut logits, &mut scratch);
            bb(&logits);
        })
        .clone();
    let s_scalar8 = b
        .bench("packed_words_scalar_b8", || {
            model.infer_batch_words_scalar(bb(&batch8_words), 8, &mut logits, &mut scratch);
            bb(&logits);
        })
        .clone();

    let speedup_b1 = s_dense1.mean_ns / s_packed1.mean_ns;
    let fps_packed8 = 8.0 / (s_packed8.mean_ns / 1e9);
    let fps_dense8 = 8.0 / (s_dense8.mean_ns / 1e9);
    println!(
        "\n→ XNOR-popcount vs dense reference: {speedup_b1:.1}× at b=1, \
         {:.1}× at b=8 ({fps_packed8:.0} vs {fps_dense8:.0} frames/s)",
        s_dense8.mean_ns / s_packed8.mean_ns
    );
    let simd_vs_scalar = s_scalar8.mean_ns / s_simd8.mean_ns;
    println!(
        "→ dispatched kernel `{}` vs scalar popcount: {simd_vs_scalar:.2}× at b=8",
        active_simd()
    );

    let payload = Value::obj(vec![
        ("suite", Value::Str("backend".into())),
        ("native_b1_ns", Value::Num(s_packed1.mean_ns)),
        ("dense_b1_ns", Value::Num(s_dense1.mean_ns)),
        ("speedup_b1", Value::Num(speedup_b1)),
        ("native_b8_ns", Value::Num(s_packed8.mean_ns)),
        ("dense_b8_ns", Value::Num(s_dense8.mean_ns)),
        (
            "speedup_b8",
            Value::Num(s_dense8.mean_ns / s_packed8.mean_ns),
        ),
        ("native_b8_fps", Value::Num(fps_packed8)),
        ("dense_b8_fps", Value::Num(fps_dense8)),
        ("simd_kernel", Value::Str(active_simd().into())),
        ("simd_b8_ns", Value::Num(s_simd8.mean_ns)),
        ("scalar_b8_ns", Value::Num(s_scalar8.mean_ns)),
        ("simd_speedup_b8", Value::Num(simd_vs_scalar)),
    ]);
    let path = "BENCH_backend.json";
    match std::fs::write(path, payload.to_string_pretty()) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    b.finish();
}
