//! PJRT runtime benches (feature `pjrt`): AOT executable latency at each
//! batch size plus the full pipeline serve throughput — the end-to-end
//! numbers quoted in EXPERIMENTS.md §Perf.  Skipped (with a notice) when
//! artifacts are absent.

use std::sync::Arc;

use pixelmtj::backend::PjrtBackend;
use pixelmtj::config::{HwConfig, PipelineConfig, SparseCoding};
use pixelmtj::coordinator::Pipeline;
use pixelmtj::runtime::Runtime;
use pixelmtj::sensor::{scene::SceneGen, FirstLayerWeights, PixelArraySim};
use pixelmtj::util::bench::{bb, Bencher};

fn main() {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("meta.json").exists() {
        println!("runtime bench skipped: run `make artifacts` first");
        return;
    }
    let runtime = Arc::new(Runtime::cpu(artifacts).unwrap());
    let meta = runtime.meta.as_ref().unwrap().clone();
    let mut b = Bencher::new("runtime");

    // Frontend + backend executables at each exported batch size.
    for &batch in &meta.batches {
        let img_n: usize =
            meta.img_shape[1..].iter().product::<usize>() * batch;
        let act_n: usize =
            meta.act_shape[1..].iter().product::<usize>() * batch;
        let mut img_shape: Vec<i64> =
            meta.img_shape.iter().map(|&d| d as i64).collect();
        img_shape[0] = batch as i64;
        let mut act_shape: Vec<i64> =
            meta.act_shape.iter().map(|&d| d as i64).collect();
        act_shape[0] = batch as i64;
        let img = vec![0.5f32; img_n];
        let act = vec![0.0f32; act_n];

        let front = runtime.load(&format!("frontend_b{batch}")).unwrap();
        b.bench(&format!("frontend_b{batch}_exec"), || {
            bb(front.run_f32(&[(&img, &img_shape)]).unwrap());
        });
        let back = runtime.load(&format!("backend_b{batch}")).unwrap();
        b.bench(&format!("backend_b{batch}_exec"), || {
            bb(back.run_f32(&[(&act, &act_shape)]).unwrap());
        });
        let full = runtime.load(&format!("full_b{batch}")).unwrap();
        b.bench(&format!("full_b{batch}_exec"), || {
            bb(full.run_f32(&[(&img, &img_shape)]).unwrap());
        });
    }

    // End-to-end pipeline throughput (64 frames per iteration) through
    // the PJRT backend behind the InferenceBackend trait.
    let hw = HwConfig::load_or_default(artifacts);
    let weights =
        FirstLayerWeights::from_golden(artifacts.join("golden.json")).unwrap();
    let mut cfg = PipelineConfig::default();
    cfg.sparse_coding = SparseCoding::Rle;
    let backend = Arc::new(PjrtBackend::from_runtime(runtime.clone()).unwrap());
    let pipeline = Pipeline::new(
        cfg,
        PixelArraySim::new(hw.clone(), weights),
        backend,
    )
    .unwrap();
    let gen = SceneGen::new(3, 32, 32);
    let frames: Vec<_> = (0..64u32).map(|i| gen.textured(i)).collect();
    let stats = b.bench("pipeline_serve_64_frames", || {
        bb(pipeline.serve(bb(frames.clone())).unwrap());
    });
    println!(
        "→ pipeline throughput ≈ {:.1} frames/s",
        64.0 / (stats.mean_ns / 1e9)
    );

    b.finish();
}
