//! Packed-representation benches → `BENCH_pack.json`: the BitPlane frame
//! path (capture writes packed words → word-level link codec → XNOR
//! backend consumes words zero-copy) against the pre-refactor legacy
//! path (bool capture → per-element dense codec → widen to f32 →
//! f32-entry backend, which re-packs per frame), at the CIFAR-scale
//! 32×32 and the paper's ImageNet/VGG16 224×224 geometries.
//!
//! Three views per geometry:
//! * **repr** — binarize→link→infer from a precomputed analog plane:
//!   isolates exactly what the representation change touches (the analog
//!   MAC/tanh stage is identical in both arms and excluded);
//! * **e2e** — full capture→infer frames/sec (analog stage included in
//!   both arms, so the ratio is diluted by the shared physics);
//! * **sweep** — Monte-Carlo cells/sec through the real engine vs an
//!   emulation of the pre-refactor engine (which recomputed the analog
//!   plane in every cell and scored flips with per-element bool loops).
//!
//! `PIXELMTJ_BENCH_FAST=1` shrinks trial counts for the CI smoke run.

use std::time::Instant;

use pixelmtj::backend::{InferenceBackend, NativeBackend};
use pixelmtj::config::{HwConfig, SparseCoding, SweepConfig};
use pixelmtj::coordinator::sparse;
use pixelmtj::sensor::{
    scene::SceneGen, CaptureMode, FirstLayerWeights, OperatingPoint,
    PixelArraySim,
};
use pixelmtj::sweep::run_sweep;
use pixelmtj::util::bench::{bb, Bencher};
use pixelmtj::util::json::Value;

/// Label from a logit vector (same tie-breaking as the serving path).
fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Legacy link emulation: the old dense codec packed bools to words and
/// unpacked back to bools, one element at a time, then widened to f32.
fn legacy_link_and_widen(bits: &[bool]) -> Vec<f32> {
    let mut words = vec![0u64; bits.len().div_ceil(64)];
    for (i, &b) in bits.iter().enumerate() {
        if b {
            words[i / 64] |= 1 << (i % 64);
        }
    }
    let mut decoded = vec![false; bits.len()];
    for (i, d) in decoded.iter_mut().enumerate() {
        *d = (words[i / 64] >> (i % 64)) & 1 == 1;
    }
    decoded.iter().map(|&b| b as u8 as f32).collect()
}

struct GeomReport {
    name: &'static str,
    side: usize,
    elems: usize,
    repr_speedup: f64,
    e2e_packed_fps: f64,
    e2e_legacy_fps: f64,
    e2e_speedup: f64,
    sweep_packed_cps: f64,
    sweep_legacy_cps: f64,
    sweep_speedup: f64,
}

fn bench_geometry(
    b: &mut Bencher,
    name: &'static str,
    side: usize,
    sweep_trials: u32,
) -> GeomReport {
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 1);
    let sim = PixelArraySim::new(hw.clone(), weights.clone());
    let backend = NativeBackend::new(hw.clone(), weights.clone(), side, side, 1);
    let gen = SceneGen::new(3, side, side);
    let frame = gen.textured(5);
    let (oh, ow) = sim.out_hw(side, side);
    let op = OperatingPoint::from_cfg(&hw.mtj);
    let elems = backend.act_elems();

    // ── repr: binarize → link → infer from a shared analog plane ──
    let (plane, _) = sim.analog_plane(&frame);
    let s_repr_packed = b
        .bench(&format!("repr_packed_{name}"), || {
            let (map, _) = sim.binarize_at(
                bb(&plane),
                oh,
                ow,
                frame.seq,
                &op,
                CaptureMode::Ideal,
            );
            let enc = sparse::encode(&map, SparseCoding::Dense);
            let dec = sparse::decode(&enc).unwrap();
            bb(backend.run_backend_packed(dec.words(), 1).unwrap());
        })
        .clone();
    let s_repr_legacy = b
        .bench(&format!("repr_legacy_{name}"), || {
            let (bits, _) = sim.binarize_at_ref(
                bb(&plane),
                frame.seq,
                &op,
                CaptureMode::Ideal,
            );
            let acts = legacy_link_and_widen(&bits);
            bb(backend.run_backend(&acts, 1).unwrap());
        })
        .clone();
    let repr_speedup = s_repr_legacy.mean_ns / s_repr_packed.mean_ns;

    // ── e2e: full capture → infer (shared analog stage included) ──
    let s_e2e_packed = b
        .bench(&format!("e2e_packed_{name}"), || {
            let (map, _) = sim.capture(bb(&frame), CaptureMode::Ideal);
            bb(backend.run_backend_packed(map.words(), 1).unwrap());
        })
        .clone();
    let s_e2e_legacy = b
        .bench(&format!("e2e_legacy_{name}"), || {
            let (bits, _) = sim.capture_ref(bb(&frame), CaptureMode::Ideal);
            let acts: Vec<f32> = bits.iter().map(|&x| x as u8 as f32).collect();
            bb(backend.run_backend(&acts, 1).unwrap());
        })
        .clone();

    // ── sweep: real engine (plane reuse + XOR scoring + packed classify)
    //    vs an emulation of the pre-refactor per-cell loop ──
    // Both sweep arms run single-threaded so the ratio isolates the
    // representation + per-campaign plane reuse, not worker count.
    let grid = "v=0.8,0.9;k=4,5";
    let cfg = SweepConfig {
        grid: grid.to_string(),
        trials: sweep_trials,
        threads: 1,
        seed: 9,
        sensor_height: side,
        sensor_width: side,
        ..SweepConfig::default()
    };
    let t0 = Instant::now();
    let summary = run_sweep(&cfg).expect("pack bench sweep failed");
    let n_cells = summary.cells.len();
    let sweep_packed_cps = n_cells as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    // Legacy emulation: ideal references once per campaign (as the old
    // engine did), then per (cell, trial): full capture_at_ref — analog
    // recomputed every time — bool flip loop, widen, f32 classify.
    let cells: Vec<OperatingPoint> = [0.8, 0.9]
        .iter()
        .flat_map(|&v| {
            [4usize, 5].map(|k| OperatingPoint { v_write: v, k, ..op })
        })
        .collect();
    let trial_frames: Vec<_> = (0..sweep_trials)
        .map(|t| gen.textured(pixelmtj::sweep::trial_seed(9, t)))
        .collect();
    let refs: Vec<(Vec<bool>, usize)> = trial_frames
        .iter()
        .map(|f| {
            let (bits, _) = sim.capture_ref(f, CaptureMode::Ideal);
            let acts: Vec<f32> = bits.iter().map(|&x| x as u8 as f32).collect();
            let label = argmax(&backend.run_backend(&acts, 1).unwrap());
            (bits, label)
        })
        .collect();
    let t0 = Instant::now();
    for cell_op in &cells {
        let mut agree = 0u32;
        let mut flips = 0u64;
        for (f, (ideal, label)) in trial_frames.iter().zip(refs.iter()) {
            let (bits, _) =
                sim.capture_at_ref(f, cell_op, CaptureMode::CalibratedMtj);
            for (&a, &b) in ideal.iter().zip(bits.iter()) {
                flips += u64::from(a != b);
            }
            let acts: Vec<f32> = bits.iter().map(|&x| x as u8 as f32).collect();
            agree +=
                u32::from(argmax(&backend.run_backend(&acts, 1).unwrap()) == *label);
        }
        bb((agree, flips));
    }
    let sweep_legacy_cps =
        cells.len() as f64 / t0.elapsed().as_secs_f64().max(1e-9);

    GeomReport {
        name,
        side,
        elems,
        repr_speedup,
        e2e_packed_fps: 1e9 / s_e2e_packed.mean_ns,
        e2e_legacy_fps: 1e9 / s_e2e_legacy.mean_ns,
        e2e_speedup: s_e2e_legacy.mean_ns / s_e2e_packed.mean_ns,
        sweep_packed_cps,
        sweep_legacy_cps,
        sweep_speedup: sweep_packed_cps / sweep_legacy_cps.max(1e-9),
    }
}

fn main() {
    let fast = std::env::var("PIXELMTJ_BENCH_FAST").is_ok();
    let mut b = Bencher::new("pack");
    let reports = vec![
        bench_geometry(&mut b, "32x32", 32, if fast { 4 } else { 16 }),
        bench_geometry(&mut b, "224x224", 224, if fast { 1 } else { 2 }),
    ];

    println!();
    for r in &reports {
        println!(
            "{:>9} ({:>6} elems): repr {:>5.1}× | e2e {:>7.1} vs {:>7.1} fps \
             ({:.2}×) | sweep {:>6.2} vs {:>6.2} cells/s ({:.1}×)",
            r.name,
            r.elems,
            r.repr_speedup,
            r.e2e_packed_fps,
            r.e2e_legacy_fps,
            r.e2e_speedup,
            r.sweep_packed_cps,
            r.sweep_legacy_cps,
            r.sweep_speedup,
        );
    }
    let r224 = &reports[1];
    if r224.repr_speedup < 2.0 {
        eprintln!(
            "warning: packed repr path {:.2}× at 224×224, below the 2× target",
            r224.repr_speedup
        );
    }

    let geom_objs: Vec<Value> = reports
        .iter()
        .map(|r| {
            Value::obj(vec![
                ("geometry", Value::Str(r.name.into())),
                ("side", Value::Num(r.side as f64)),
                ("act_elems", Value::Num(r.elems as f64)),
                ("repr_speedup", Value::Num(r.repr_speedup)),
                ("e2e_packed_fps", Value::Num(r.e2e_packed_fps)),
                ("e2e_legacy_fps", Value::Num(r.e2e_legacy_fps)),
                ("e2e_speedup", Value::Num(r.e2e_speedup)),
                ("sweep_packed_cells_per_sec", Value::Num(r.sweep_packed_cps)),
                ("sweep_legacy_cells_per_sec", Value::Num(r.sweep_legacy_cps)),
                ("sweep_speedup", Value::Num(r.sweep_speedup)),
            ])
        })
        .collect();
    let payload = Value::obj(vec![
        ("suite", Value::Str("pack".into())),
        ("repr_speedup_224", Value::Num(r224.repr_speedup)),
        ("e2e_speedup_224", Value::Num(r224.e2e_speedup)),
        ("sweep_speedup_224", Value::Num(r224.sweep_speedup)),
        ("geometries", Value::Arr(geom_objs)),
    ]);
    let path = "BENCH_pack.json";
    match std::fs::write(path, payload.to_string_pretty()) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    b.finish();
}
