//! Sensor + pipeline benches: per-frame capture cost in each fidelity
//! mode (the L3 hot path), the analog-plane MAC loop, shutter timing
//! model, and Fig. 4(a) circuit sweep.  These regenerate the performance
//! side of the paper's §3.4 latency story on this testbed.

use pixelmtj::circuit::pixel::fig4a_scatter;
use pixelmtj::config::HwConfig;
use pixelmtj::sensor::{
    scene::SceneGen, CaptureMode, FirstLayerWeights, GlobalShutter,
    PixelArraySim, RollingShutter,
};
use pixelmtj::util::bench::{bb, Bencher};

fn main() {
    let hw = HwConfig::default();
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 1);
    let sim = PixelArraySim::new(hw.clone(), weights);
    let gen = SceneGen::new(3, 32, 32);
    let frame = gen.textured(3);
    let mut b = Bencher::new("pipeline");

    b.bench("scene_gen_32x32", || {
        bb(gen.textured(bb(9)));
    });

    b.bench("analog_plane_32x32", || {
        bb(sim.analog_plane(bb(&frame)));
    });

    b.bench("capture_ideal_32x32", || {
        bb(sim.capture(bb(&frame), CaptureMode::Ideal));
    });

    b.bench("capture_calibrated_mtj_32x32", || {
        bb(sim.capture(bb(&frame), CaptureMode::CalibratedMtj));
    });

    // PhysicalMtj is the slow ablation path — bench on a smaller frame.
    let small = SceneGen::new(3, 16, 16).textured(4);
    b.bench("capture_physical_mtj_16x16", || {
        bb(sim.capture(bb(&small), CaptureMode::PhysicalMtj));
    });

    let gs = GlobalShutter::new(hw.clone());
    let rs = RollingShutter::new(hw.clone());
    b.bench("shutter_timing_models", || {
        bb(gs.frame_timing(224, 224, bb(0.25)));
        bb(rs.frame_timing(224, 224));
    });

    b.bench("fig4a_sweep_2000pts", || {
        bb(fig4a_scatter(&hw.circuit, 2000, bb(7)));
    });

    b.finish();
}
