//! Link-codec benches (paper §3.2): encode/decode cost per frame for the
//! three codings at trained-BNN sparsity (~80 %) and at dense-activation
//! stress (50 %).  The encode path sits on the sensor workers' critical
//! path, so ns/frame here bounds pipeline throughput.

use pixelmtj::config::{KeyedEnum, SparseCoding};
use pixelmtj::coordinator::sparse::{decode, encode};
use pixelmtj::device::rng::CounterRng;
use pixelmtj::sensor::BitPlane;
use pixelmtj::util::bench::{bb, Bencher};

fn random_map(p_one: f32, seed: u32) -> BitPlane {
    let mut rng = CounterRng::new(seed, 31);
    let bools: Vec<bool> =
        (0..32 * 15 * 15).map(|_| rng.next_uniform() < p_one).collect();
    BitPlane::from_bools(32, 15, 15, &bools, seed).unwrap()
}

fn main() {
    let mut b = Bencher::new("sparse");
    for (label, p) in [("sparse80", 0.20f32), ("dense50", 0.50f32)] {
        let map = random_map(p, 5);
        for coding in
            [SparseCoding::Dense, SparseCoding::Csr, SparseCoding::Rle]
        {
            let enc = encode(&map, coding);
            println!(
                "payload {label} {:?}: {} bits ({:.3} b/elem)",
                coding,
                enc.payload_bits,
                enc.payload_bits as f64 / map.len() as f64
            );
            b.bench(&format!("encode_{label}_{}", coding.name()), || {
                bb(encode(bb(&map), coding));
            });
            b.bench(&format!("decode_{label}_{}", coding.name()), || {
                bb(decode(bb(&enc)).unwrap());
            });
        }
    }
    b.finish();
}
