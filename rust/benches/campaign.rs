//! Distributed campaign bench: coordinator-side cells/sec as loopback
//! worker processes scale 1 → 2 → 4, vs the single-process sweep on the
//! same grid.  Emits `BENCH_campaign.json` so the distribution
//! overhead (protocol + journal fsync per cell) is machine-diffable
//! across PRs; `PIXELMTJ_BENCH_FAST=1` shrinks the campaign for CI.
//!
//! Every tier hard-asserts the acceptance claim on its way out: the
//! reassembled campaign report is byte-identical to `run_sweep` of the
//! same grid/seed, whatever the worker count.
//!
//! Workers here are in-process threads driving real loopback TCP
//! sessions through `run_worker` — the same protocol path as separate
//! processes, minus fork overhead, so cells/sec isolates coordination
//! cost rather than process startup.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use pixelmtj::campaign::{
    run_coordinator, run_worker, CampaignOptions, DEFAULT_LEASE_TTL,
};
use pixelmtj::config::SweepConfig;
use pixelmtj::reports::sweep_report;
use pixelmtj::sweep::run_sweep;
use pixelmtj::util::json::Value;

fn campaign_cfg(fast: bool) -> SweepConfig {
    SweepConfig {
        // 12 cells fast / 20 cells full — enough leases that 4 workers
        // all see work at 2 cells per lease.
        grid: if fast {
            "v=0.7,0.8,0.9;k=4,5;sigma=0,0.02".to_string()
        } else {
            "v=0.7,0.75,0.8,0.85,0.9;k=4,5;sigma=0,0.02".to_string()
        },
        trials: if fast { 4 } else { 16 },
        threads: 2,
        seed: 13,
        sensor_height: if fast { 16 } else { 24 },
        sensor_width: if fast { 16 } else { 24 },
        ..SweepConfig::default()
    }
}

struct TierResult {
    workers: usize,
    cells_per_sec: f64,
    wall_secs: f64,
}

fn run_tier(cfg: &SweepConfig, workers: usize, reference: &str) -> TierResult {
    let dir = std::env::temp_dir().join(format!(
        "pixelmtj-bench-campaign-{}-{workers}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = CampaignOptions {
        listen: "127.0.0.1:0".to_string(),
        lease_cells: 2,
        checkpoint: dir.join("campaign.journal"),
        lease_ttl: DEFAULT_LEASE_TTL,
    };

    let started = Instant::now();
    let (tx, rx) = mpsc::channel();
    let coordinator = {
        let cfg = cfg.clone();
        std::thread::spawn(move || {
            run_coordinator(
                &cfg,
                &opts,
                None,
                |addr| {
                    let _ = tx.send(addr);
                },
                |_idx, _cell| {},
            )
            .expect("coordinator run")
        })
    };
    let addr = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("coordinator listen address")
        .to_string();
    let handles: Vec<_> = (0..workers)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || run_worker(&addr, 1, 0))
        })
        .collect();
    let mut completed = 0u64;
    for h in handles {
        completed += h
            .join()
            .expect("worker thread")
            .expect("worker run")
            .cells_completed;
    }
    let summary = coordinator.join().expect("coordinator thread");
    let wall = started.elapsed().as_secs_f64();

    assert_eq!(completed, summary.cells.len() as u64, "lost cells");
    assert_eq!(
        sweep_report::to_json(&summary).to_string_pretty(),
        reference,
        "campaign over {workers} workers must serialize byte-identical \
         to run_sweep"
    );
    let _ = std::fs::remove_dir_all(&dir);

    TierResult {
        workers,
        cells_per_sec: summary.cells.len() as f64 / wall.max(1e-9),
        wall_secs: wall,
    }
}

fn main() {
    let fast = std::env::var("PIXELMTJ_BENCH_FAST").is_ok();
    let cfg = campaign_cfg(fast);

    let started = Instant::now();
    let single = run_sweep(&cfg).expect("reference sweep");
    let single_wall = started.elapsed().as_secs_f64();
    let cells = single.cells.len();
    let single_rate = cells as f64 / single_wall.max(1e-9);
    let reference = sweep_report::to_json(&single).to_string_pretty();
    println!(
        "campaign bench: {cells} cells × {} trials at {}×{}\n\
         single-process sweep ({} threads): {single_rate:>8.1} cells/s\n",
        cfg.trials, cfg.sensor_height, cfg.sensor_width, cfg.threads
    );

    let mut runs = Vec::new();
    for workers in [1usize, 2, 4] {
        let r = run_tier(&cfg, workers, &reference);
        println!(
            "workers={}: {:>8.1} cells/s  ({:.2} s wall, byte-identical ✓)",
            r.workers, r.cells_per_sec, r.wall_secs
        );
        runs.push(r);
    }

    let run_objs: Vec<Value> = runs
        .iter()
        .map(|r| {
            Value::obj(vec![
                ("workers", Value::Num(r.workers as f64)),
                ("cells_per_sec", Value::Num(r.cells_per_sec)),
                ("wall_secs", Value::Num(r.wall_secs)),
            ])
        })
        .collect();
    let payload = Value::obj(vec![
        ("suite", Value::Str("campaign".into())),
        ("cells", Value::Num(cells as f64)),
        ("trials", Value::Num(cfg.trials as f64)),
        ("single_process_cells_per_sec", Value::Num(single_rate)),
        ("runs", Value::Arr(run_objs)),
    ]);
    let path = "BENCH_campaign.json";
    match std::fs::write(path, payload.to_string_pretty()) {
        Ok(()) => println!("\n[saved {path}]"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
