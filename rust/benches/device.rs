//! Device-model benches: the Monte-Carlo substrate under every figure
//! (Figs. 2, 5) and the per-neuron write/read/reset hot path that bounds
//! the PhysicalMtj capture mode.

use pixelmtj::config::{CircuitConfig, MtjConfig};
use pixelmtj::circuit::readout::BurstReader;
use pixelmtj::device::{neuron_error_rates, Mtj, MtjModel, MtjState, MultiMtjNeuron};
use pixelmtj::util::bench::{bb, Bencher};

fn main() {
    let cfg = MtjConfig::default();
    let model = MtjModel::new(&cfg);
    let mut b = Bencher::new("device");

    b.bench("switching_probability", || {
        bb(model.switching_probability(MtjState::AntiParallel, bb(0.8), 0.7));
    });

    b.bench("tmr_and_resistance", || {
        bb(model.resistance(MtjState::AntiParallel, bb(0.3)));
    });

    let mut i = 0u32;
    b.bench("single_mtj_pulse", || {
        let mut d = Mtj::new();
        i = i.wrapping_add(1);
        bb(d.apply_pulse(&model, 0.8, 0.7, 7, i, 0));
    });

    let mut j = 0u32;
    b.bench("neuron_write8_read_reset", || {
        let mut n = MultiMtjNeuron::new(8);
        j = j.wrapping_add(1);
        n.write_analog(&model, 0.85, 11, j);
        bb(n.count_parallel());
        bb(n.reset_all(&model, 11, j, 16));
    });

    let ccfg = CircuitConfig::default();
    let reader = BurstReader::new(&model, &ccfg);
    let mut k = 0u32;
    b.bench("burst_read_and_reset", || {
        let mut n = MultiMtjNeuron::new(8);
        k = k.wrapping_add(1);
        n.write_analog(&model, 0.85, 13, k);
        bb(reader.read_and_reset(&model, &mut n, 13, k));
    });

    b.bench("fig5_binomial_analysis", || {
        for n in [1usize, 2, 4, 8] {
            bb(neuron_error_rates(0.924, 0.062, n, n / 2 + 1));
        }
    });

    b.finish();
}
