//! Streaming server bench: frames/sec and e2e latency vs sensor-worker
//! count and batch policy on the steady-rate workload.  Emits
//! `BENCH_stream.json` so the scaling trajectory is machine-diffable
//! across PRs; `PIXELMTJ_BENCH_FAST=1` shrinks the workload for CI.
//!
//! The acceptance claim this file pins: multi-worker throughput on the
//! steady workload is at least single-worker throughput (the sensor-sim
//! stage is the CPU-bound one, so sharding it must not hurt).

use std::sync::Arc;

use pixelmtj::backend::NativeBackend;
use pixelmtj::config::{HwConfig, PipelineConfig, Workload};
use pixelmtj::coordinator::{feed, make_source, Pipeline};
use pixelmtj::sensor::{FirstLayerWeights, PixelArraySim};
use pixelmtj::util::json::Value;

struct RunResult {
    workers: usize,
    batch_sizes: Vec<usize>,
    fps: f64,
    p50_us: u64,
    p99_us: u64,
    mean_occupancy: f64,
}

fn run_stream(
    workers: usize,
    batch_sizes: Vec<usize>,
    frames: u32,
) -> anyhow::Result<RunResult> {
    let hw = HwConfig::default();
    let cfg = PipelineConfig {
        sensor_workers: workers,
        batch_sizes: batch_sizes.clone(),
        workload: Workload::Steady,
        ..PipelineConfig::default()
    };
    let weights = FirstLayerWeights::synthetic(32, 3, 3, 1);
    let sim = PixelArraySim::new(hw.clone(), weights.clone());
    // Deliberately not Pipeline::synthetic_native: the backend's internal
    // batch pool stays constant across runs so multi_worker_speedup
    // isolates the sensor-stage sharding, not backend threading.
    let backend = Arc::new(NativeBackend::new(
        hw.clone(),
        weights,
        cfg.sensor_height,
        cfg.sensor_width,
        PipelineConfig::default().sensor_workers,
    ));
    let channels = hw.network.in_channels;
    let pipeline = Pipeline::new(cfg, sim, backend)?;

    let mut source = make_source(pipeline.config(), channels, frames);
    let server = pipeline.stream()?;
    if let Err(feed_err) = feed(&server, &mut *source) {
        return Err(server.fail_shutdown(feed_err));
    }
    let report = server.shutdown()?;
    anyhow::ensure!(
        report.results.len() == frames as usize,
        "lost frames: {} of {frames}",
        report.results.len()
    );

    let metrics = pipeline.metrics();
    Ok(RunResult {
        workers,
        batch_sizes,
        fps: report.fps,
        p50_us: metrics.e2e_latency.quantile_us(0.5),
        p99_us: metrics.e2e_latency.quantile_us(0.99),
        mean_occupancy: metrics.mean_batch_occupancy(),
    })
}

fn main() {
    let fast = std::env::var("PIXELMTJ_BENCH_FAST").is_ok();
    let frames: u32 = if fast { 192 } else { 768 };
    let worker_counts = [1usize, 2, 4];
    let policies: [&[usize]; 2] = [&[1], &[1, 8]];

    println!("stream bench: steady workload, {frames} frames per run\n");
    let mut runs = Vec::new();
    for &workers in &worker_counts {
        for policy in policies {
            let r = run_stream(workers, policy.to_vec(), frames)
                .expect("stream run failed");
            println!(
                "workers={} batch_sizes={:?}: {:>8.1} fps  e2e p50 ≤ {} µs  \
                 p99 ≤ {} µs  (occupancy {:.2})",
                r.workers,
                r.batch_sizes,
                r.fps,
                r.p50_us,
                r.p99_us,
                r.mean_occupancy
            );
            runs.push(r);
        }
    }

    // The scaling headline: best multi-worker vs single-worker throughput
    // under the dynamic {1,8} policy.
    let fps_of = |w: usize| {
        runs.iter()
            .filter(|r| r.workers == w && r.batch_sizes == [1, 8])
            .map(|r| r.fps)
            .next()
            .unwrap_or(0.0)
    };
    let single = fps_of(1);
    let multi = worker_counts[1..]
        .iter()
        .map(|&w| fps_of(w))
        .fold(0.0f64, f64::max);
    println!(
        "\n→ steady workload: single-worker {single:.1} fps, best \
         multi-worker {multi:.1} fps ({:.2}× scaling)",
        multi / single.max(1e-9)
    );

    let run_objs: Vec<Value> = runs
        .iter()
        .map(|r| {
            Value::obj(vec![
                ("workers", Value::Num(r.workers as f64)),
                (
                    "batch_sizes",
                    Value::Str(
                        r.batch_sizes
                            .iter()
                            .map(|s| s.to_string())
                            .collect::<Vec<_>>()
                            .join(","),
                    ),
                ),
                ("fps", Value::Num(r.fps)),
                ("e2e_p50_us_le", Value::Num(r.p50_us as f64)),
                ("e2e_p99_us_le", Value::Num(r.p99_us as f64)),
                ("mean_batch_occupancy", Value::Num(r.mean_occupancy)),
            ])
        })
        .collect();
    let payload = Value::obj(vec![
        ("suite", Value::Str("stream".into())),
        ("workload", Value::Str("steady".into())),
        ("frames_per_run", Value::Num(frames as f64)),
        ("single_worker_fps", Value::Num(single)),
        ("multi_worker_fps", Value::Num(multi)),
        ("multi_worker_speedup", Value::Num(multi / single.max(1e-9))),
        ("runs", Value::Arr(run_objs)),
    ]);
    let path = "BENCH_stream.json";
    match std::fs::write(path, payload.to_string_pretty()) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
