//! Sweep engine scaling bench: cells/sec vs worker count on a fixed
//! campaign grid → `BENCH_sweep.json`.
//!
//! The acceptance claim this file pins: the campaign is embarrassingly
//! parallel across cells, so throughput scales with workers (>2× from
//! 1 → 4 on a ≥4-core machine; on smaller machines the speedup is
//! core-bound and the JSON records whatever was measured).
//! `PIXELMTJ_BENCH_FAST=1` shrinks trials for CI smoke runs.

use std::time::Instant;

use pixelmtj::config::SweepConfig;
use pixelmtj::sweep::run_sweep;
use pixelmtj::util::json::Value;

struct Run {
    threads: usize,
    cells: usize,
    wall_s: f64,
    cells_per_sec: f64,
}

/// 24 cells spanning voltage × majority × variability — uniform per-cell
/// cost, several cells per worker at every measured thread count.
const GRID: &str = "v=0.7,0.75,0.8,0.85,0.9,0.95;k=4,5;sigma=0,0.05";

fn run(threads: usize, trials: u32) -> Run {
    let cfg = SweepConfig {
        grid: GRID.to_string(),
        trials,
        threads,
        seed: 9,
        ..SweepConfig::default()
    };
    let t0 = Instant::now();
    let summary = run_sweep(&cfg).expect("sweep bench run failed");
    let wall_s = t0.elapsed().as_secs_f64();
    Run {
        threads,
        cells: summary.cells.len(),
        wall_s,
        cells_per_sec: summary.cells.len() as f64 / wall_s.max(1e-9),
    }
}

fn main() {
    let fast = std::env::var("PIXELMTJ_BENCH_FAST").is_ok();
    let trials: u32 = if fast { 8 } else { 32 };
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "sweep bench: {trials} trials/cell on grid {GRID} \
         ({cores} cores available)\n"
    );

    // Warm-up run so page faults / lazy init don't tax the 1-thread
    // baseline.
    let _ = run(1, 1);

    let worker_counts = [1usize, 2, 4];
    let mut runs = Vec::new();
    for &threads in &worker_counts {
        let r = run(threads, trials);
        println!(
            "threads={:<2} {:>3} cells in {:>6.2} s → {:>7.2} cells/s",
            r.threads, r.cells, r.wall_s, r.cells_per_sec
        );
        runs.push(r);
    }

    let cps = |t: usize| {
        runs.iter()
            .find(|r| r.threads == t)
            .map(|r| r.cells_per_sec)
            .unwrap_or(0.0)
    };
    let speedup_2 = cps(2) / cps(1).max(1e-9);
    let speedup_4 = cps(4) / cps(1).max(1e-9);
    println!(
        "\n→ scaling: 1→2 workers {speedup_2:.2}×, 1→4 workers \
         {speedup_4:.2}×"
    );
    if speedup_4 < 2.0 && cores >= 4 {
        eprintln!(
            "warning: 1→4 scaling {speedup_4:.2}× below the 2× target \
             on a {cores}-core machine"
        );
    }

    let run_objs: Vec<Value> = runs
        .iter()
        .map(|r| {
            Value::obj(vec![
                ("threads", Value::Num(r.threads as f64)),
                ("cells", Value::Num(r.cells as f64)),
                ("wall_s", Value::Num(r.wall_s)),
                ("cells_per_sec", Value::Num(r.cells_per_sec)),
            ])
        })
        .collect();
    let payload = Value::obj(vec![
        ("suite", Value::Str("sweep".into())),
        ("grid", Value::Str(GRID.into())),
        ("trials_per_cell", Value::Num(trials as f64)),
        ("cores_available", Value::Num(cores as f64)),
        ("speedup_1_to_2", Value::Num(speedup_2)),
        ("speedup_1_to_4", Value::Num(speedup_4)),
        ("runs", Value::Arr(run_objs)),
    ]);
    let path = "BENCH_sweep.json";
    match std::fs::write(path, payload.to_string_pretty()) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
