//! Wire ingest bench: throughput, round-trip latency, and bandwidth of
//! the readiness-reactor front door across a sessions × batch-size grid.
//! Emits `BENCH_wire.json` so the ingest trajectory is machine-diffable
//! across PRs; `PIXELMTJ_BENCH_FAST=1` shrinks the grid for CI.
//!
//! The acceptance claim this file pins: FRAME_BATCH envelopes (protocol
//! v2, batch ≥ 8) ship strictly fewer protocol bytes per frame and
//! strictly fewer envelopes than the same frames as v1 FRAMEs.
//!
//! Per tier it reports:
//! * `fps` — pipelined end-to-end throughput over all sessions;
//! * `rt_p99_us` — p99 of serialized envelope round trips (send one
//!   FRAME / FRAME_BATCH, wait for every RESULT) on a dedicated probe
//!   session, i.e. unloaded protocol + pipeline latency;
//! * `bytes_per_frame` — client-counted protocol bytes / frames;
//! * `envelopes` — client→server envelope count (HELLO + frames);
//! * `threads_mid_run` — `/proc/self/task` size while the load is in
//!   flight (−1 where /proc is unavailable): the reactor's "no thread
//!   per session" claim as a number.

use std::io::Write;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use pixelmtj::config::{HwConfig, WireCoding};
use pixelmtj::sensor::{scene::SceneGen, Frame};
use pixelmtj::system::System;
use pixelmtj::util::json::Value;
use pixelmtj::wire::{proto, Msg, StatusCode, WireClient, VERSION, VERSION_V2};

struct TierResult {
    sessions: usize,
    batch: usize,
    fps: f64,
    rt_p99_us: u64,
    bytes_per_frame: f64,
    envelopes: u64,
    threads_mid_run: i64,
}

fn thread_count() -> i64 {
    std::fs::read_dir("/proc/self/task")
        .map(|d| d.count() as i64)
        .unwrap_or(-1)
}

/// Serialized round trips on a fresh session: one envelope out, all of
/// its RESULTs back, timed.  Returns the p99 in µs.
fn latency_probe(
    addr: &str,
    version: u16,
    batch: usize,
    frames: &[Frame],
    channels: usize,
    height: usize,
    width: usize,
) -> u64 {
    let mut stream = TcpStream::connect(addr).expect("probe connect");
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let deadline = Instant::now() + Duration::from_secs(120);
    let overdue = move || Instant::now() > deadline;
    stream
        .write_all(
            &Msg::Hello {
                version,
                coding: WireCoding::Csr,
                channels: channels as u16,
                height: height as u32,
                width: width as u32,
            }
            .encode(),
        )
        .expect("probe HELLO");
    match proto::read_msg(&mut stream, &overdue).expect("probe ACK") {
        proto::MsgOutcome::Msg(Msg::HelloAck { .. }) => {}
        other => panic!("probe expected HELLO_ACK, got {other:?}"),
    }

    let mut rtts: Vec<Duration> = Vec::new();
    for chunk in frames.chunks(batch.max(1)).take(32) {
        let msg = if batch > 1 {
            Msg::FrameBatch {
                first_seq: chunk[0].seq,
                coding: WireCoding::Csr,
                bodies: chunk
                    .iter()
                    .map(|f| proto::encode_frame_body(f, WireCoding::Csr))
                    .collect(),
            }
        } else {
            Msg::Frame {
                seq: chunk[0].seq,
                coding: WireCoding::Csr,
                body: proto::encode_frame_body(&chunk[0], WireCoding::Csr),
            }
        };
        let t0 = Instant::now();
        stream.write_all(&msg.encode()).expect("probe envelope");
        let mut got = 0usize;
        while got < chunk.len() {
            match proto::read_msg(&mut stream, &overdue).expect("probe read")
            {
                proto::MsgOutcome::Msg(Msg::Result { .. }) => got += 1,
                proto::MsgOutcome::Msg(Msg::ResultBatch { results }) => {
                    got += results.len()
                }
                other => panic!("probe expected results, got {other:?}"),
            }
        }
        rtts.push(t0.elapsed());
    }
    stream
        .write_all(&Msg::Goodbye { code: StatusCode::Ok }.encode())
        .expect("probe GOODBYE");
    loop {
        match proto::read_msg(&mut stream, &overdue) {
            Ok(proto::MsgOutcome::Msg(Msg::Goodbye { .. })) | Err(_) => break,
            Ok(proto::MsgOutcome::Msg(_)) => {}
            Ok(proto::MsgOutcome::Eof | proto::MsgOutcome::Stopped) => break,
        }
    }

    rtts.sort_unstable();
    let idx = (rtts.len().saturating_sub(1)) * 99 / 100;
    rtts.get(idx).map(|d| d.as_micros() as u64).unwrap_or(0)
}

fn run_tier(
    sessions: usize,
    batch: usize,
    frames_per_session: u32,
) -> TierResult {
    let mut sys = System::builder()
        .artifacts_dir("/nonexistent")
        .workers(2)
        .listen("127.0.0.1:0")
        .max_sessions(sessions as u64 + 4)
        .build();
    let mut svc = sys.serve_wire().expect("wire server");
    let addr = svc.server.local_addr().to_string();
    let channels = HwConfig::default().network.in_channels;
    let (height, width) = (
        sys.spec().pipeline.sensor_height,
        sys.spec().pipeline.sensor_width,
    );
    let gen = SceneGen::new(channels, height, width);
    let frames: Vec<Frame> =
        (0..frames_per_session).map(|i| gen.textured(i)).collect();
    let version = if batch > 1 { VERSION_V2 } else { VERSION };

    // Throughput pass: `sessions` pipelined clients on their own threads
    // (client threads belong to the load generator, not the server — the
    // thread snapshot below is what the server side adds).
    let started = Instant::now();
    let mut handles = Vec::new();
    for _ in 0..sessions {
        let addr = addr.clone();
        let frames = frames.clone();
        handles.push(std::thread::spawn(
            move || -> anyhow::Result<(usize, u64, u64)> {
                let mut client = WireClient::connect_versioned(
                    &addr, version, WireCoding::Csr, channels, height, width,
                )?;
                if batch > 1 {
                    for chunk in frames.chunks(batch) {
                        client.send_batch(chunk)?;
                    }
                } else {
                    for frame in &frames {
                        client.send_frame(frame)?;
                    }
                }
                let bytes = client.bytes_sent();
                let envelopes = client.envelopes_sent();
                Ok((client.finish()?.len(), bytes, envelopes))
            },
        ));
    }
    std::thread::sleep(Duration::from_millis(50));
    let threads_mid_run = thread_count();
    let mut results = 0usize;
    let mut bytes = 0u64;
    let mut envelopes = 0u64;
    for h in handles {
        let (n, b, e) = h.join().expect("client thread").expect("client run");
        results += n;
        bytes += b;
        envelopes += e;
    }
    let wall = started.elapsed().as_secs_f64();
    let want = sessions * frames_per_session as usize;
    assert_eq!(results, want, "lost results");

    let rt_p99_us = latency_probe(
        &addr, version, batch, &frames, channels, height, width,
    );
    svc.server.shutdown();

    TierResult {
        sessions,
        batch,
        fps: want as f64 / wall.max(1e-9),
        rt_p99_us,
        bytes_per_frame: bytes as f64 / want as f64,
        envelopes,
        threads_mid_run,
    }
}

fn main() {
    let fast = std::env::var("PIXELMTJ_BENCH_FAST").is_ok();
    let frames_per_session: u32 = if fast { 64 } else { 256 };
    let session_counts: &[usize] = if fast { &[1, 4] } else { &[1, 4, 16] };
    let batch_sizes: &[usize] = if fast { &[1, 8] } else { &[1, 8, 32] };

    println!(
        "wire bench: csr coding, {frames_per_session} frames per session\n"
    );
    let mut runs = Vec::new();
    for &sessions in session_counts {
        for &batch in batch_sizes {
            let r = run_tier(sessions, batch, frames_per_session);
            println!(
                "sessions={} batch={:>2}: {:>8.1} fps  rt p99 {} µs  \
                 {:>7.1} B/frame  {} envelopes  {} threads mid-run",
                r.sessions,
                r.batch,
                r.fps,
                r.rt_p99_us,
                r.bytes_per_frame,
                r.envelopes,
                r.threads_mid_run,
            );
            runs.push(r);
        }
    }

    // The headline: v2 batching vs v1 per-frame envelopes, one session.
    let tier = |batch: usize| {
        runs.iter()
            .find(|r| r.sessions == 1 && r.batch == batch)
            .expect("grid holds the comparison tiers")
    };
    let v1 = tier(1);
    let batched = tier(*batch_sizes.last().unwrap());
    assert!(
        batched.bytes_per_frame < v1.bytes_per_frame,
        "batching must cut bytes/frame ({} vs {})",
        batched.bytes_per_frame,
        v1.bytes_per_frame
    );
    assert!(
        batched.envelopes < v1.envelopes,
        "batching must cut envelopes ({} vs {})",
        batched.envelopes,
        v1.envelopes
    );
    println!(
        "\n→ batch={}: {:.1} → {:.1} B/frame ({:.1}% saved), {} → {} \
         envelopes",
        batched.batch,
        v1.bytes_per_frame,
        batched.bytes_per_frame,
        100.0 * (1.0 - batched.bytes_per_frame / v1.bytes_per_frame),
        v1.envelopes,
        batched.envelopes,
    );

    let run_objs: Vec<Value> = runs
        .iter()
        .map(|r| {
            Value::obj(vec![
                ("sessions", Value::Num(r.sessions as f64)),
                ("batch_frames", Value::Num(r.batch as f64)),
                ("fps", Value::Num(r.fps)),
                ("rt_p99_us", Value::Num(r.rt_p99_us as f64)),
                ("bytes_per_frame", Value::Num(r.bytes_per_frame)),
                ("envelopes", Value::Num(r.envelopes as f64)),
                ("threads_mid_run", Value::Num(r.threads_mid_run as f64)),
            ])
        })
        .collect();
    let payload = Value::obj(vec![
        ("suite", Value::Str("wire".into())),
        ("coding", Value::Str("csr".into())),
        ("frames_per_session", Value::Num(frames_per_session as f64)),
        ("v1_bytes_per_frame", Value::Num(v1.bytes_per_frame)),
        ("batched_bytes_per_frame", Value::Num(batched.bytes_per_frame)),
        (
            "batch_bytes_saving",
            Value::Num(1.0 - batched.bytes_per_frame / v1.bytes_per_frame),
        ),
        ("runs", Value::Arr(run_objs)),
    ]);
    let path = "BENCH_wire.json";
    match std::fs::write(path, payload.to_string_pretty()) {
        Ok(()) => println!("[saved {path}]"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
